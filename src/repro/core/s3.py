"""S3-compatible object-store backend: the paper's claim made literal.

The filesystem :class:`~repro.core.store.ObjectStore` mirrors an S3 key
scheme precisely so a real object-store backend is a drop-in replacement —
this module is that replacement.  :class:`S3Backend` implements the full
:class:`~repro.core.store.StoreBackend` contract over an S3-style REST
dialect, so ``push``/``pull``/``clone``, the run-cache closure transfer,
tiered reads and remote-side GC all run against commodity object storage
with no catalog service in between:

    keyspace        ``<bucket>/objects/<d0d1>/<d2...>``  framed blob payloads
                    ``<bucket>/refs/<name>``             tiny digest pointers

    GET / HEAD / PUT / DELETE <key>        object + ref bytes
    GET ?list-type=2&prefix=&start-after=  ListObjectsV2-style paged listing
    PUT + If-Match / If-None-Match         conditional writes → ref CAS

Blobs are stored in the same framed (magic + codec byte) form the
filesystem store uses at rest, so an S3 bucket and a store directory are
byte-compatible mirrors of each other, and encoded wire transfers
(``get_encoded``/``put_encoded``) pass payloads straight through without
recompressing.

Ref atomicity over plain conditional writes:

* ``cas_ref`` is a read-compare-conditional-write loop: the version token
  (ETag) captured at read time guards the write, so a racing writer makes
  the conditional PUT fail with 412 instead of silently losing an update —
  the loop re-reads and either retries (value still matches ``expected``)
  or raises :class:`~repro.core.errors.RefConflict`.
* ``cas_refs`` preflights EVERY expectation (capturing version tokens)
  before writing anything — a stale expectation updates nothing — then
  applies token-guarded conditional writes; a mid-batch 412 (concurrent
  racer) rolls the already-applied refs back.  Unlike the server-side
  ``cas_refs`` of :class:`~repro.core.remote.RemoteServer` the
  conflict-then-rollback window is briefly visible to concurrent readers
  (S3 has no multi-key transaction), which is the same contract as the
  sync layer's per-ref fallback — and what the conformance matrix pins.

A transport fault *during* a conditional write raises
:class:`~repro.core.errors.AmbiguousRefUpdate` (the write may have landed;
see docs/remote_store.md), never a plain failure.

Against a real S3/GCS endpoint only auth signing is missing (out of scope
here); ``tests/``'s :mod:`repro.core.s3stub` serves the same dialect from
the stdlib so the whole stack is testable with zero new dependencies.
"""

from __future__ import annotations

import threading
import urllib.parse
import xml.etree.ElementTree as ET
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

from .errors import (AmbiguousRefUpdate, ObjectNotFound, RefConflict,
                     RefNotFound, RemoteError)
from .store import decode_frame, encode_frame, sha256_hex

_OBJ_PREFIX = "objects/"
_REF_PREFIX = "refs/"
_CAS_ATTEMPTS = 4  # re-read/retry rounds before a contended CAS gives up


def _object_key(digest: str) -> str:
    return f"{_OBJ_PREFIX}{digest[:2]}/{digest[2:]}"


def _digest_of_key(key: str) -> str:
    return key[len(_OBJ_PREFIX):].replace("/", "", 1)


def _ref_key(name: str) -> str:
    for part in name.split("/"):
        if not part or part.startswith("."):
            raise ValueError(f"bad ref name {name!r}")
    return _REF_PREFIX + name


def _local_name(tag: str) -> str:
    """XML tag without its namespace (real S3 responses are namespaced,
    the stub's are not — match both)."""
    return tag.rsplit("}", 1)[-1]


class S3Backend:
    """``StoreBackend`` over an S3-compatible REST endpoint.

    >>> remote = S3Backend("http://127.0.0.1:9000", "lake")
    >>> remote.put(b"blob")            # PUT objects/…, framed + compressed
    >>> remote.cas_ref("branch=main", None, digest)   # If-None-Match: *

    ``pool`` bounds the HEAD/GET/PUT fan-out used to batch ``has_many`` /
    ``get_many`` / ``put_many`` — the S3 dialect has no server-side batch
    ops, so batching is client-side concurrency over per-thread
    connections.
    """

    def __init__(self, endpoint: str, bucket: str, *, timeout: float = 30.0,
                 retries: int = 2, pool: int = 8, codec: str = "auto",
                 level: int = 3):
        parsed = urllib.parse.urlsplit(endpoint)
        if parsed.scheme not in ("http", "https"):
            raise ValueError(f"unsupported endpoint scheme {parsed.scheme!r}")
        if not bucket or "/" in bucket:
            raise ValueError(f"bad bucket name {bucket!r}")
        self.endpoint = endpoint.rstrip("/")
        self.bucket = bucket
        self.scheme = parsed.scheme
        self.host = parsed.hostname or "127.0.0.1"
        self.port = parsed.port or (443 if parsed.scheme == "https" else 80)
        self.timeout = timeout
        self.retries = retries
        self.pool = max(1, pool)
        self.codec = codec
        self.level = level
        self._local = threading.local()
        self._executor: Optional[ThreadPoolExecutor] = None
        self._executor_lock = threading.Lock()

    @classmethod
    def from_url(cls, url: str, **kw) -> "S3Backend":
        """``s3://host:port/bucket`` → a backend over plain-HTTP (the stub
        dialect; a signing layer for real S3 endpoints would slot in
        here)."""
        parsed = urllib.parse.urlsplit(url)
        if parsed.scheme != "s3":
            raise ValueError(f"not an s3 URL: {url!r}")
        bucket = parsed.path.strip("/")
        if not bucket:
            raise ValueError(f"s3 URL missing a bucket: {url!r}")
        host = parsed.hostname or "127.0.0.1"
        port = f":{parsed.port}" if parsed.port else ""
        return cls(f"http://{host}{port}", bucket, **kw)

    # ----------------------------------------------------------- plumbing
    def _conn(self):
        conn = getattr(self._local, "conn", None)
        if conn is None:
            import http.client

            cls = (http.client.HTTPSConnection if self.scheme == "https"
                   else http.client.HTTPConnection)
            conn = cls(self.host, self.port, timeout=self.timeout)
            self._local.conn = conn
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            try:
                conn.close()
            finally:
                self._local.conn = None

    def _request(self, method: str, key: str, *, body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None,
                 query: Optional[Dict[str, str]] = None,
                 idempotent: bool = True):
        """One REST round-trip → ``(status, headers, body)``.

        Idempotent requests (everything except conditional writes) retry
        on transport faults; a conditional write that faults mid-flight
        raises :class:`AmbiguousRefUpdate` because the server may have
        applied it."""
        # percent-encode the key (the server decodes): ref names may carry
        # spaces/%/?/# — sent raw they would break http.client, truncate at
        # the query separator, or alias with their decoded spelling
        path = "/" + self.bucket + (
            "/" + urllib.parse.quote(key, safe="/") if key else "")
        if query:
            path += "?" + urllib.parse.urlencode(query)
        attempts = 1 + (self.retries if idempotent else 0)
        last: Optional[Exception] = None
        for _ in range(attempts):
            conn = self._conn()
            try:
                conn.request(method, path, body=body, headers=headers or {})
                resp = conn.getresponse()
                data = resp.read()
                # normalize header names: servers spell ETag/Etag/etag
                # differently, and a missed version token would break CAS
                return (resp.status,
                        {k.lower(): v for k, v in resp.getheaders()}, data)
            except Exception as e:  # noqa: BLE001 - socket/http.client zoo
                self._drop_conn()
                last = e
        if not idempotent:
            raise AmbiguousRefUpdate(
                f"{method} {key}: transport failed after a conditional "
                f"write may have been delivered ({last!r}); ref state is "
                "unknown — re-read to resolve") from last
        raise RemoteError(f"{method} {key}: transport failed after "
                          f"{attempts} attempts ({last!r})") from last

    def close(self) -> None:
        self._drop_conn()
        with self._executor_lock:
            executor, self._executor = self._executor, None
        if executor is not None:
            executor.shutdown(wait=True)

    # ------------------------------------------------------------ objects
    def _encode(self, data: bytes) -> bytes:
        return encode_frame(data, codec=self.codec, level=self.level)

    def put(self, data: bytes) -> str:
        digest = sha256_hex(data)
        status, _h, _b = self._request(
            "PUT", _object_key(digest), body=self._encode(data))
        if status not in (200, 201, 204):
            raise RemoteError(f"put {digest}: HTTP {status}")
        return digest

    def get(self, digest: str) -> bytes:
        data = decode_frame(self.get_encoded(digest),
                            what=f"object {digest}")
        if sha256_hex(data) != digest:  # never trust the wire
            raise ObjectNotFound(f"digest mismatch for {digest} from s3")
        return data

    def has(self, digest: str) -> bool:
        status, _h, _b = self._request("HEAD", _object_key(digest))
        if status == 200:
            return True
        if status == 404:
            return False
        # anything else (503 throttle, 403) must NOT read as "absent":
        # the GC mark phase trusts has(), and a swallowed server error
        # would let the sweep delete live objects
        raise RemoteError(f"head {digest}: HTTP {status}")

    def _fan_out(self, fn, items):
        """Run ``fn`` over ``items`` on a bounded pool (order-preserving).
        The pool is persistent per backend so worker threads keep their
        per-thread connections alive across calls (a sync moves many small
        chunks — a fresh pool per chunk would pay a TCP connect per worker
        per chunk and leak the old sockets to the GC)."""
        if len(items) <= 1:
            return [fn(x) for x in items]
        with self._executor_lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(max_workers=self.pool)
            pool = self._executor
        return list(pool.map(fn, items))

    def has_many(self, digests: Iterable[str]) -> Set[str]:
        digests = list(digests)
        present = self._fan_out(self.has, digests)
        return {d for d, ok in zip(digests, present) if ok}

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        return dict(zip(digests, self._fan_out(self.get, digests)))

    def put_many(self, blobs: Sequence[bytes]) -> List[str]:
        return self._fan_out(self.put, list(blobs))

    def size(self, digest: str) -> int:
        """Stored (framed/compressed) size, same semantics as the
        filesystem store's on-disk size."""
        status, headers, _b = self._request("HEAD", _object_key(digest))
        if status != 200:
            raise ObjectNotFound(digest)
        return int(headers.get("content-length", 0))

    def mtime(self, digest: str) -> float:
        """Upload time from the ``Last-Modified`` response header — the
        age source for the GC grace window over S3.  A server that omits
        the header reads as *just uploaded* (never sweepable inside the
        window): the failure mode of missing age data must be "kept a
        garbage blob another hour", never "deleted an in-flight upload"."""
        return self.stat(digest)[1]

    def stat(self, digest: str) -> Tuple[int, float]:
        """``(stored size, Last-Modified)`` from ONE HEAD request — the
        per-candidate cost of a grace-window sweep over the dialect."""
        import email.utils
        import time as _time

        status, headers, _b = self._request("HEAD", _object_key(digest))
        if status == 404:
            raise ObjectNotFound(digest)
        if status != 200:
            raise RemoteError(f"head {digest}: HTTP {status}")
        size = int(headers.get("content-length", 0))
        stamp = headers.get("last-modified")
        if not stamp:
            return size, _time.time()
        try:
            return size, email.utils.parsedate_to_datetime(
                stamp).timestamp()
        except (TypeError, ValueError):
            return size, _time.time()

    def touch_many(self, digests: Sequence[str]) -> int:
        """S3 has no cheap mtime refresh (a self-copy per object would
        cost a mutating request each) — report 0 touched; pushes that
        dedup against an S3 remote stay protected by the GC generation
        token's retry path instead."""
        return 0

    def delete_object(self, digest: str) -> bool:
        """Remote-side GC sweep primitive.  Idempotent: missing → False."""
        status, _h, _b = self._request("DELETE", _object_key(digest))
        if status in (200, 204):
            return True
        if status == 404:
            return False
        raise RemoteError(f"delete {digest}: HTTP {status}")

    # -------------------------------------------------- encoded payloads
    def get_encoded(self, digest: str) -> bytes:
        status, _h, body = self._request("GET", _object_key(digest))
        if status == 404:
            raise ObjectNotFound(digest)
        if status != 200:
            raise RemoteError(f"get {digest}: HTTP {status}")
        return body

    def put_encoded(self, payload: bytes) -> str:
        # decode to learn + verify the digest, upload the ORIGINAL payload:
        # compression paid at the source is never re-paid here
        digest = sha256_hex(decode_frame(payload, what="encoded payload"))
        status, _h, _b = self._request(
            "PUT", _object_key(digest), body=payload)
        if status not in (200, 201, 204):
            raise RemoteError(f"put {digest}: HTTP {status}")
        return digest

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        return dict(zip(digests, self._fan_out(self.get_encoded, digests)))

    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]:
        # the digest hint is ignored: the S3 dialect has no server-side
        # verification, so the client-side decode here is the only check
        # standing between a corrupt payload and the bucket
        return self._fan_out(self.put_encoded, list(payloads))

    # ------------------------------------------------------------ listing
    def _list_keys(self, prefix: str, *, start_after: Optional[str],
                   limit: int) -> Tuple[List[str], bool]:
        """One ListObjectsV2-style page: ``(sorted keys, truncated)``.

        Truncation comes from the response's ``IsTruncated`` field, never
        from comparing the page size to ``limit`` — servers cap max-keys
        (S3: 1000), so a short page can still have more behind it."""
        query = {"list-type": "2", "prefix": prefix,
                 "max-keys": str(max(1, limit))}
        if start_after:
            query["start-after"] = start_after
        status, _h, body = self._request("GET", "", query=query)
        if status != 200:
            raise RemoteError(f"list {prefix!r}: HTTP {status}")
        try:
            root = ET.fromstring(body)
        except ET.ParseError as e:
            raise RemoteError(f"list {prefix!r}: malformed XML ({e})") from e
        keys: List[str] = []
        truncated = False
        for el in root.iter():
            name = _local_name(el.tag)
            if name == "Contents":
                for child in el:
                    if _local_name(child.tag) == "Key":
                        keys.append(child.text or "")
            elif name == "IsTruncated":
                truncated = (el.text or "").strip().lower() == "true"
        return keys, truncated

    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000) -> Tuple[List[str], Optional[str]]:
        limit = max(1, limit)
        start = _object_key(page_token) if page_token else None
        keys, truncated = self._list_keys(_OBJ_PREFIX, start_after=start,
                                          limit=limit)
        page = [_digest_of_key(k) for k in keys]
        return page, (page[-1] if page and truncated else None)

    def iter_objects(self) -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_objects(page_token=token)
            yield from page
            if token is None:
                return

    # --------------------------------------------------------------- refs
    def _read_ref(self, name: str) -> Tuple[Optional[str], Optional[str]]:
        """Current ``(value, version_token)`` of a ref; (None, None) when
        it does not exist.  The token guards conditional writes."""
        status, headers, body = self._request("GET", _ref_key(name))
        if status == 404:
            return None, None
        if status != 200:
            raise RemoteError(f"get_ref {name}: HTTP {status}")
        return body.decode().strip(), headers.get("etag")

    def get_ref(self, name: str) -> str:
        value, _etag = self._read_ref(name)
        if value is None:
            raise RefNotFound(name)
        return value

    def set_ref(self, name: str, digest: str) -> None:
        status, _h, _b = self._request(
            "PUT", _ref_key(name), body=digest.encode())
        if status not in (200, 201, 204):
            raise RemoteError(f"set_ref {name}: HTTP {status}")

    def delete_ref(self, name: str) -> None:
        status, _h, _b = self._request("DELETE", _ref_key(name))
        if status == 404:
            raise RefNotFound(name)
        if status not in (200, 204):
            raise RemoteError(f"delete_ref {name}: HTTP {status}")

    def _conditional_put(self, name: str, digest: str,
                         etag: Optional[str]) -> Tuple[bool, Optional[str]]:
        """Token-guarded ref write: ``If-Match`` against the captured
        version, ``If-None-Match: *`` for create-only.  Returns
        ``(applied, new_etag)``; False means 412 (a racer moved the ref
        between our read and this write)."""
        headers = ({"If-Match": etag} if etag is not None
                   else {"If-None-Match": "*"})
        status, resp_headers, _b = self._request(
            "PUT", _ref_key(name), body=digest.encode(), headers=headers,
            idempotent=False)
        if status == 412:
            return False, None
        if status not in (200, 201, 204):
            raise RemoteError(f"cas_ref {name}: HTTP {status}")
        return True, resp_headers.get("etag")

    def _conditional_delete(self, name: str, etag: str) -> None:
        """Token-guarded ref delete (rollback of a create): 412 means a
        racer moved the ref since our write — their update stays."""
        status, _h, _b = self._request(
            "DELETE", _ref_key(name), headers={"If-Match": etag},
            idempotent=False)
        if status not in (200, 204, 404, 412):
            raise RemoteError(f"conditional delete {name}: HTTP {status}")

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        """Compare-and-set via conditional write.

        Value semantics match :meth:`ObjectStore.cas_ref` exactly: the
        *current value* is compared against ``expected``; the version
        token only makes the read-compare-write atomic (a 412 from a
        concurrent writer re-reads instead of clobbering)."""
        for _ in range(_CAS_ATTEMPTS):
            current, etag = self._read_ref(name)
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r}")
            applied, _new_etag = self._conditional_put(name, new, etag)
            if applied:
                return
        raise RefConflict(
            f"ref {name}: conditional write kept losing races "
            f"({_CAS_ATTEMPTS} attempts)")

    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None:
        """Multi-ref CAS over conditional writes.

        Every expectation is validated (and its version token captured)
        before ANY write — one stale expectation updates nothing.  The
        token-guarded writes then apply in order; a mid-batch 412 from a
        concurrent racer rolls the applied prefix back.  See the module
        docstring for how this differs from a server-side transactional
        ``cas_refs``."""
        tokens: List[Optional[str]] = []
        for name, expected, _new in updates:
            current, etag = self._read_ref(name)
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r} "
                    "(no ref in this batch was updated)")
            tokens.append(etag)
        applied: List[Tuple[str, Optional[str], Optional[str]]] = []
        for (name, expected, new), etag in zip(updates, tokens):
            try:
                ok, new_etag = self._conditional_put(name, new, etag)
            except AmbiguousRefUpdate:
                # the write may have landed before the fault: resolve by
                # re-read so a mid-batch fault can never leave the prefix
                # torn behind an "unknown" diagnosis
                try:
                    current, cur_etag = self._read_ref(name)
                except RemoteError:
                    self._rollback(applied)
                    raise
                if current == new:
                    ok, new_etag = True, cur_etag  # it DID apply: continue
                else:
                    self._rollback(applied)
                    raise RemoteError(
                        f"ref {name}: transport fault during conditional "
                        "write; the ref was re-read and verified unchanged "
                        "— applied refs were rolled back") from None
            except RemoteError:
                self._rollback(applied)
                raise
            if not ok:
                self._rollback(applied)
                raise RefConflict(
                    f"ref {name}: lost a race mid-batch; already-applied "
                    "refs were rolled back")
            applied.append((name, expected, new_etag))

    def _rollback(self, applied) -> None:
        """Best-effort restore of already-applied conditional writes."""
        for name, expected, new_etag in reversed(applied):
            try:
                if expected is None:
                    # we created it: undo is a delete — guarded by OUR
                    # write's token, so a racer who CASed the ref onward
                    # since keeps their committed update (412, not clobber)
                    if new_etag is not None:
                        self._conditional_delete(name, new_etag)
                    else:
                        self.delete_ref(name)
                else:
                    # guarded by OUR write's token: if a racer moved the
                    # ref since, the 412 leaves their update in place
                    self._conditional_put(name, expected, new_etag)
            except (RemoteError, RefConflict, RefNotFound):
                pass  # best effort: the racer's update wins

    def iter_refs(self, prefix: str = "") -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_refs(prefix, page_token=token)
            for name, _digest in page:
                yield name
            if token is None:
                return

    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]:
        limit = max(1, limit)
        start = _REF_PREFIX + page_token if page_token else None
        keys, truncated = self._list_keys(_REF_PREFIX + prefix,
                                          start_after=start, limit=limit)
        names = [k[len(_REF_PREFIX):] for k in keys]
        values = self._fan_out(lambda n: self._read_ref(n)[0], names)
        page = [(n, v) for n, v in zip(names, values) if v is not None]
        return page, (names[-1] if names and truncated else None)
