"""Iceberg-style tables: snapshots + manifests over tensor files (Fig. 2, layer 3).

A *snapshot* is an immutable, content-addressed metadata object:

    { schema, manifest: [ {digest, nrows, nbytes, stats}, ... ],
      parent: <snapshot digest | None>, op: "append"|"overwrite", seq }

The level of indirection is exactly the paper's point (§3.2): users reason
about schema evolution and table snapshots; inserts/updates produce a new
immutable snapshot that downstream systems reference as a stable state.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence

import msgpack
import numpy as np

from . import tensorfile
from .errors import SchemaError
from .store import ObjectStore
from .tensorfile import Schema


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass(frozen=True)
class ManifestEntry:
    digest: str
    nrows: int
    nbytes: int
    stats: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self):
        return [self.digest, self.nrows, self.nbytes, self.stats]

    @staticmethod
    def from_obj(o):
        return ManifestEntry(o[0], o[1], o[2], o[3])


@dataclass(frozen=True)
class Snapshot:
    schema: Schema
    manifest: tuple  # tuple[ManifestEntry]
    parent: Optional[str]
    op: str
    seq: int

    @property
    def nrows(self) -> int:
        return sum(e.nrows for e in self.manifest)

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self.manifest)

    def to_obj(self):
        return {
            "schema": self.schema.to_obj(),
            "manifest": [e.to_obj() for e in self.manifest],
            "parent": self.parent,
            "op": self.op,
            "seq": self.seq,
        }

    @staticmethod
    def from_obj(o) -> "Snapshot":
        return Snapshot(
            schema=Schema.from_obj(o["schema"]),
            manifest=tuple(ManifestEntry.from_obj(e) for e in o["manifest"]),
            parent=o["parent"],
            op=o["op"],
            seq=o["seq"],
        )


class TableIO:
    """Write/read path between in-memory columns and snapshots.

    write: columns → tensorfile blob(s) → manifest → snapshot digest
    read:  snapshot digest → manifest → tensorfile blobs → columns
    (the reversible hierarchy of Fig. 2).
    """

    def __init__(self, store: ObjectStore, *, target_rows_per_file: int = 65536,
                 on_read=None):
        self.store = store
        self.target_rows_per_file = target_rows_per_file
        #: optional callback fired with each snapshot digest a read touches
        #: — the read-set capture hook transactions use (``core/txn.py``):
        #: a pipeline node that only holds the IO handle still contributes
        #: the tables it reads to its transaction's declared set
        self.on_read = on_read

    def with_read_recorder(self, on_read) -> "TableIO":
        """A sibling handle over the same store whose reads fire
        ``on_read(snapshot_digest)`` (this handle is left untouched)."""
        return TableIO(self.store,
                       target_rows_per_file=self.target_rows_per_file,
                       on_read=on_read)

    # ------------------------------------------------------------------ write
    def write_snapshot(
        self,
        cols: Mapping[str, np.ndarray],
        *,
        parent: Optional[str] = None,
        op: str = "overwrite",
    ) -> str:
        """Persist columns as a new snapshot; returns the snapshot digest."""
        entries: List[ManifestEntry] = []
        schema: Optional[Schema] = None
        seq = 0
        if parent is not None:
            parent_snap = self.load_snapshot(parent)
            seq = parent_snap.seq + 1
            if op == "append":
                entries.extend(parent_snap.manifest)
                schema = parent_snap.schema

        for chunk in _row_chunks(cols, self.target_rows_per_file):
            blob, meta = tensorfile.encode(chunk)
            digest = self.store.put(blob)
            chunk_schema = Schema.from_obj(meta["schema"])
            if schema is None:
                schema = chunk_schema
            else:
                schema.check_compatible(chunk_schema)
            entries.append(
                ManifestEntry(digest, meta["nrows"], meta["nbytes"], meta["stats"])
            )
        if schema is None:
            raise SchemaError("empty snapshot")
        snap = Snapshot(schema, tuple(entries), parent, op, seq)
        return self.store.put(_pack(snap.to_obj()))

    def append(self, parent: str, cols: Mapping[str, np.ndarray]) -> str:
        return self.write_snapshot(cols, parent=parent, op="append")

    # ------------------------------------------------------------------- read
    def load_snapshot(self, digest: str) -> Snapshot:
        return Snapshot.from_obj(_unpack(self.store.get(digest)))

    def iter_files(self, digest: str) -> Iterator[Dict[str, np.ndarray]]:
        if self.on_read is not None:
            self.on_read(digest)
        snap = self.load_snapshot(digest)
        for entry in snap.manifest:
            yield tensorfile.decode(self.store.get(entry.digest))

    def read(self, digest: str, columns: Optional[Sequence[str]] = None
             ) -> Dict[str, np.ndarray]:
        frames = list(self.iter_files(digest))
        cols = tensorfile.concat(frames)
        if columns is not None:
            missing = set(columns) - cols.keys()
            if missing:
                raise SchemaError(f"missing columns {sorted(missing)}")
            cols = {k: cols[k] for k in columns}
        return cols

    def history(self, digest: str) -> List[str]:
        """Snapshot lineage, newest first (time travel within one table)."""
        out, cur = [], digest
        while cur is not None:
            out.append(cur)
            cur = self.load_snapshot(cur).parent
        return out


def _row_chunks(cols: Mapping[str, np.ndarray], rows_per_file: int):
    arrays = {k: np.asarray(v) for k, v in cols.items()}
    if not arrays:
        raise SchemaError("no columns")
    n = next(iter(arrays.values())).shape[0]
    if n == 0:
        raise SchemaError("empty columns")
    for start in range(0, n, rows_per_file):
        stop = min(start + rows_per_file, n)
        yield {k: v[start:stop] for k, v in arrays.items()}
