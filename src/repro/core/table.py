"""Iceberg-style tables: a three-level metadata hierarchy over tensor files.

Fig. 2, layer 3 — but with the real Iceberg shape instead of a flat file
list.  A table snapshot is the root of a content-addressed tree:

    snapshot blob        { v:1, schema, manifest_list: <digest>,
                           parent, op, seq, nrows, nbytes }
    manifest-list blob   { v:1, manifests: [[digest, nrows, nbytes,
                           nfiles, zone], ...] }
    manifest blob        { v:1, entries: [[digest, nrows, nbytes,
                           stats], ...] }
    tensorfile blobs     the data files themselves

Every level is immutable and content addressed, so the hierarchy dedups in
the store: an **append writes O(delta) metadata** — one new manifest blob
for the new files plus a small manifest-list and snapshot blob — and reuses
every parent manifest *verbatim* (same digest, no copy, no re-upload on
push).  Each manifest-list row carries a **zone map** (per-column min/max/
null-count rolled up from the per-file stats), so a predicate scan prunes
whole manifests with one comparison before it prunes files, and never
fetches a data blob that provably contains no matching row.

Row order is part of the table's logical contents: manifests in list
order, entries in manifest order, rows in file order.  That makes
:meth:`TableIO.logical_digest` well-defined — the fingerprint compaction
uses to *prove* a rewrite lossless (``core/compact.py``).

Legacy format (v0, pre-hierarchy) stored the flat entry list inline in the
snapshot blob under ``"manifest"`` with no ``"v"`` key.  The decoder still
reads those: they surface as a single inline :class:`ManifestFile`, and the
first append on top of one materializes it as a real manifest blob — the
migration path is "touch the table".
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Mapping, Optional,
                    Sequence, Set, Tuple)

import msgpack
import numpy as np

from . import frame as _frame
from . import tensorfile
from .errors import SchemaError
from .frame import Expr
from .store import ObjectStore
from .tensorfile import Schema

_SNAPSHOT_VERSION = 1


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass(frozen=True)
class ManifestEntry:
    """One data file: tensorfile digest + row/byte counts + column stats."""

    digest: str
    nrows: int
    nbytes: int
    stats: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self):
        return [self.digest, self.nrows, self.nbytes, self.stats]

    @staticmethod
    def from_obj(o):
        return ManifestEntry(o[0], o[1], o[2], o[3])


@dataclass(frozen=True)
class ManifestFile:
    """One manifest: a content-addressed batch of data files plus the
    zone-map rollup that lets a scan skip the whole batch in one check.

    ``digest`` is the manifest blob's content address (None until the
    snapshot is stored, or for a legacy-v0 inline manifest that was never
    materialized).  ``entries`` is the inline entry tuple when it is
    already in memory — freshly written manifests and legacy decodes carry
    it; manifests loaded from a manifest-list don't, and are fetched
    lazily via :meth:`TableIO.manifest_entries`."""

    digest: Optional[str]
    nrows: int
    nbytes: int
    nfiles: int
    zone: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    entries: Optional[tuple] = None

    def key(self):
        """Identity for manifest-diffing (``txn.rebase_append``): the blob
        digest when stored, else the ordered data-file digests."""
        if self.digest is not None:
            return self.digest
        return tuple(e.digest for e in (self.entries or ()))


@dataclass(frozen=True)
class Snapshot:
    schema: Schema
    manifests: tuple  # tuple[ManifestFile], scan order
    parent: Optional[str]
    op: str  # "overwrite" | "append" | "compact"
    seq: int

    @property
    def nrows(self) -> int:
        return sum(m.nrows for m in self.manifests)

    @property
    def nbytes(self) -> int:
        return sum(m.nbytes for m in self.manifests)

    @property
    def nfiles(self) -> int:
        return sum(m.nfiles for m in self.manifests)


# ------------------------------------------------------------ manifest blobs
def pack_manifest(entries: Sequence[ManifestEntry]) -> bytes:
    return _pack({"v": 1, "kind": "manifest",
                  "entries": [e.to_obj() for e in entries]})


def unpack_manifest(blob: bytes) -> Tuple[ManifestEntry, ...]:
    obj = _unpack(blob)
    if obj.get("kind") != "manifest":
        raise SchemaError(f"not a manifest blob (kind={obj.get('kind')!r})")
    return tuple(ManifestEntry.from_obj(e) for e in obj["entries"])


def zone_of(entries: Iterable[ManifestEntry]) -> Dict[str, Dict[str, Any]]:
    """Roll per-file column stats up into one zone map.

    A column appears in the zone only when *every* entry has stats for it
    (an entry with empty stats — non-numeric or zero-size column — makes
    the column unknown, so the scan conservatively keeps the manifest).
    ``min``/``max`` bound the non-null values across all entries; they are
    omitted when no entry has any (an all-NaN column).  ``null_count``
    sums the per-file NaN counts (integer columns have none)."""
    entries = list(entries)
    if not entries:
        return {}
    names = set(entries[0].stats)
    for e in entries[1:]:
        names &= set(e.stats)
    zone: Dict[str, Dict[str, Any]] = {}
    for name in sorted(names):
        mins, maxs, nulls, known = [], [], 0, True
        for e in entries:
            st = e.stats.get(name)
            if not st:  # empty stats: pruning on this column is unsound
                known = False
                break
            nulls += int(st.get("nan_count", 0))
            if "min" in st:
                mins.append(st["min"])
                maxs.append(st["max"])
        if not known:
            continue
        info: Dict[str, Any] = {"null_count": nulls}
        if mins:
            info["min"] = min(mins)
            info["max"] = max(maxs)
        zone[name] = info
    return zone


def inline_manifest(entries: Tuple[ManifestEntry, ...]) -> ManifestFile:
    """A not-yet-stored manifest carrying its entries inline."""
    return ManifestFile(
        digest=None,
        nrows=sum(e.nrows for e in entries),
        nbytes=sum(e.nbytes for e in entries),
        nfiles=len(entries),
        zone=zone_of(entries),
        entries=entries,
    )


# -------------------------------------------------- zone-map predicate logic
_CMP_OPS = frozenset({"gt", "ge", "lt", "le", "eq", "ne"})
_MIRROR = {"gt": "lt", "lt": "gt", "ge": "le", "le": "ge",
           "eq": "eq", "ne": "ne"}


def expr_columns(e: Optional[Expr]) -> Set[str]:
    """Column names a predicate reads — what a projected scan must decode
    beyond the requested columns to evaluate the row filter."""
    if e is None:
        return set()
    if e.op == "col":
        return {e.args[0]}
    if e.op == "lit":
        return set()
    out: Set[str] = set()
    for a in e.args:
        if isinstance(a, Expr):
            out |= expr_columns(a)
    return out


def zone_may_match(e: Expr, zone: Mapping[str, Mapping[str, Any]],
                   nrows: int) -> bool:
    """False only when the zone map PROVES no row satisfies ``e`` — the
    pruning test.  Sound by construction: every judgment is a tri-state
    over-approximation ``(may_true, may_false)``, and anything the zone
    cannot bound (arithmetic, col-vs-col, non-numeric columns) collapses
    to (True, True), i.e. "cannot prune".  NumPy NaN semantics are
    honored: a NaN row compares False under every operator except ``!=``,
    which compares True."""
    return _zone_eval(e, zone, nrows)[0]


def _zone_eval(e: Expr, zone, nrows: int) -> Tuple[bool, bool]:
    if nrows == 0:
        return (False, False)
    if e.op == "not":
        mt, mf = _zone_eval(e.args[0], zone, nrows)
        return (mf, mt)
    if e.op == "and":
        a = _zone_eval(e.args[0], zone, nrows)
        b = _zone_eval(e.args[1], zone, nrows)
        return (a[0] and b[0], a[1] or b[1])
    if e.op == "or":
        a = _zone_eval(e.args[0], zone, nrows)
        b = _zone_eval(e.args[1], zone, nrows)
        return (a[0] or b[0], a[1] and b[1])
    if e.op in _CMP_OPS:
        return _zone_cmp(e.op, e.args[0], e.args[1], zone, nrows)
    return (True, True)


def _zone_cmp(op: str, lhs: Expr, rhs: Expr, zone, nrows: int
              ) -> Tuple[bool, bool]:
    if lhs.op == "lit" and rhs.op == "col":
        lhs, rhs, op = rhs, lhs, _MIRROR[op]
    if lhs.op != "col" or rhs.op != "lit":
        return (True, True)
    info = zone.get(lhs.args[0])
    value = rhs.args[0]
    if isinstance(value, (np.integer, np.floating, np.bool_)):
        value = value.item()
    if info is None or not isinstance(value, (bool, int, float)):
        return (True, True)
    # int/float comparisons below are exact in Python (no precision-losing
    # cast), so int64 bounds near 2**63 prune correctly
    nulls = int(info.get("null_count", 0))
    has_range = "min" in info  # paired with "max" by construction
    lo, hi = info.get("min"), info.get("max")
    if isinstance(value, float) and math.isnan(value):
        # NaN literal: every comparison is False except !=, which is True
        if op == "ne":
            return (True, False)
        return (False, True)
    if not has_range:  # all rows NaN: only != matches
        if op == "ne":
            return (True, False)
        return (False, True)
    if op == "eq":
        return (lo <= value <= hi,
                nulls > 0 or lo != value or hi != value)
    if op == "ne":
        return (nulls > 0 or lo != value or hi != value,
                lo <= value <= hi)
    if op == "gt":
        return (hi > value, nulls > 0 or lo <= value)
    if op == "ge":
        return (hi >= value, nulls > 0 or lo < value)
    if op == "lt":
        return (lo < value, nulls > 0 or hi >= value)
    return (lo <= value, nulls > 0 or hi > value)  # le


class TableIO:
    """Write/read path between in-memory columns and snapshots.

    write: columns → tensorfile blobs → manifest → manifest-list → snapshot
    read:  snapshot digest → manifest-list → (zone-pruned) manifests →
           (stat-pruned) tensorfile blobs → columns
    (the reversible hierarchy of Fig. 2, now three metadata levels deep).
    """

    def __init__(self, store: ObjectStore, *, target_rows_per_file: int = 65536,
                 on_read=None):
        self.store = store
        self.target_rows_per_file = target_rows_per_file
        #: optional callback fired with each snapshot digest a read touches
        #: — the read-set capture hook transactions use (``core/txn.py``):
        #: a pipeline node that only holds the IO handle still contributes
        #: the tables it reads to its transaction's declared set
        self.on_read = on_read

    def with_read_recorder(self, on_read) -> "TableIO":
        """A sibling handle over the same store whose reads fire
        ``on_read(snapshot_digest)`` (this handle is left untouched)."""
        return TableIO(self.store,
                       target_rows_per_file=self.target_rows_per_file,
                       on_read=on_read)

    # ------------------------------------------------------------------ write
    def write_snapshot(
        self,
        cols: Mapping[str, np.ndarray],
        *,
        parent: Optional[str] = None,
        op: str = "overwrite",
    ) -> str:
        """Persist columns as a new snapshot; returns the snapshot digest.

        ``op="append"`` is O(delta): the parent's manifests are reused
        *verbatim* (same blobs, same digests — the store dedups them) and
        the new rows land as exactly one new manifest, however many files
        they chunk into."""
        schema: Optional[Schema] = None
        seq = 0
        parent_manifests: tuple = ()
        if parent is not None:
            parent_snap = self.load_snapshot(parent)
            seq = parent_snap.seq + 1
            if op == "append":
                parent_manifests = parent_snap.manifests
                schema = parent_snap.schema

        entries: List[ManifestEntry] = []
        for chunk in _row_chunks(cols, self.target_rows_per_file):
            blob, meta = tensorfile.encode(chunk)
            digest = self.store.put(blob)
            chunk_schema = Schema.from_obj(meta["schema"])
            if schema is None:
                schema = chunk_schema
            else:
                schema.check_compatible(chunk_schema)
            entries.append(
                ManifestEntry(digest, meta["nrows"], meta["nbytes"], meta["stats"])
            )
        if schema is None:
            raise SchemaError("empty snapshot")
        manifests = parent_manifests + (inline_manifest(tuple(entries)),)
        snap = Snapshot(schema, manifests, parent, op, seq)
        return self.store_snapshot(snap)

    def append(self, parent: str, cols: Mapping[str, np.ndarray]) -> str:
        return self.write_snapshot(cols, parent=parent, op="append")

    def append_stream(self, parent: Optional[str],
                      batches: Iterable[Mapping[str, np.ndarray]]) -> str:
        """Micro-batch ingestion: land each batch as one append snapshot
        chained on the previous (``parent=None`` starts the table with the
        first batch).  Each step costs O(batch) data + O(delta) metadata,
        so sustained ingest rate is flat in table size; run
        ``core/compact.py`` behind the stream to fold the small fragments
        back into ``target_rows_per_file``-sized files.  Returns the final
        snapshot digest."""
        head = parent
        for batch in batches:
            head = (self.write_snapshot(batch) if head is None
                    else self.append(head, batch))
        if head is None:
            raise SchemaError("append_stream: no batches")
        return head

    def store_snapshot(self, snap: Snapshot) -> str:
        """Persist a :class:`Snapshot` tree: materialize inline manifests
        as content-addressed blobs, then the manifest-list, then the
        snapshot root.  Already-stored manifests are referenced by digest
        — re-putting them is a no-op thanks to content addressing."""
        stored: List[ManifestFile] = []
        for mf in snap.manifests:
            if mf.digest is None:
                digest = self.store.put(pack_manifest(mf.entries or ()))
                mf = ManifestFile(digest, mf.nrows, mf.nbytes, mf.nfiles,
                                  mf.zone, mf.entries)
            stored.append(mf)
        mlist = _pack({
            "v": 1,
            "kind": "manifest_list",
            "manifests": [[m.digest, m.nrows, m.nbytes, m.nfiles, m.zone]
                          for m in stored],
        })
        obj = {
            "v": _SNAPSHOT_VERSION,
            "schema": snap.schema.to_obj(),
            "manifest_list": self.store.put(mlist),
            "parent": snap.parent,
            "op": snap.op,
            "seq": snap.seq,
            "nrows": snap.nrows,
            "nbytes": snap.nbytes,
        }
        return self.store.put(_pack(obj))

    # ------------------------------------------------------------------- read
    def load_snapshot(self, digest: str) -> Snapshot:
        obj = _unpack(self.store.get(digest))
        mlist_digest = obj.get("manifest_list")
        if mlist_digest is not None:  # v1 hierarchy
            mlist = _unpack(self.store.get(mlist_digest))
            manifests = tuple(
                ManifestFile(digest=row[0], nrows=row[1], nbytes=row[2],
                             nfiles=row[3], zone=row[4])
                for row in mlist["manifests"])
        else:  # legacy v0: flat entry list inline in the snapshot blob
            entries = tuple(ManifestEntry.from_obj(e)
                            for e in obj["manifest"])
            manifests = (inline_manifest(entries),) if entries else ()
        return Snapshot(
            schema=Schema.from_obj(obj["schema"]),
            manifests=manifests,
            parent=obj["parent"],
            op=obj["op"],
            seq=obj["seq"],
        )

    def manifest_entries(self, mf: ManifestFile) -> Tuple[ManifestEntry, ...]:
        """The manifest's data-file entries, fetching the blob if they are
        not inline."""
        if mf.entries is not None:
            return mf.entries
        return unpack_manifest(self.store.get(mf.digest))

    def iter_files(self, digest: str,
                   columns: Optional[Sequence[str]] = None,
                   where: Optional[Expr] = None
                   ) -> Iterator[Dict[str, np.ndarray]]:
        """Decoded data files of a snapshot, in row order.

        ``columns`` pushes projection into the tensorfile decode — columns
        outside the selection (plus any the predicate needs) are never
        materialized.  ``where`` prunes at two levels before any data blob
        is fetched: a manifest whose zone map proves no row can match is
        skipped whole (its manifest blob is not even read), then each
        surviving file is re-tested against its own per-file stats.
        Pruning is sound, not exact — callers still apply the row filter
        (:meth:`read` does)."""
        if self.on_read is not None:
            self.on_read(digest)
        snap = self.load_snapshot(digest)
        need: Optional[List[str]] = None
        if columns is not None:
            need = list(dict.fromkeys(
                list(columns) + sorted(expr_columns(where))))
            known = set(snap.schema.names())
            missing = sorted(set(need) - known)
            if missing:
                raise SchemaError(f"missing columns {missing}")
        for mf in snap.manifests:
            if where is not None and not zone_may_match(where, mf.zone,
                                                        mf.nrows):
                continue  # whole manifest pruned: blob never fetched
            for entry in self.manifest_entries(mf):
                if where is not None and not zone_may_match(
                        where, zone_of((entry,)), entry.nrows):
                    continue  # file pruned by its own stats
                yield tensorfile.decode(self.store.get(entry.digest),
                                        columns=need)

    def read(self, digest: str, columns: Optional[Sequence[str]] = None,
             where: Optional[Expr] = None) -> Dict[str, np.ndarray]:
        """Materialize (a projection/selection of) a snapshot.

        Equivalent to decoding everything and filtering in memory — the
        zone-map pruning in :meth:`iter_files` plus the exact row filter
        applied here guarantee it (property-tested in
        tests/test_table_format.py) — but selective predicates skip most
        data blobs entirely."""
        frames = list(self.iter_files(digest, columns=columns, where=where))
        if not frames:  # every fragment pruned: empty, correctly typed
            snap = self.load_snapshot(digest)
            names = list(columns) if columns is not None \
                else snap.schema.names()
            spec = {c.name: c for c in snap.schema.columns}
            missing = sorted(set(names) - set(spec))
            if missing:
                raise SchemaError(f"missing columns {missing}")
            return {n: np.zeros((0, *spec[n].row_shape),
                                dtype=tensorfile.resolve_dtype(spec[n].dtype))
                    for n in names}
        cols = tensorfile.concat(frames)
        if where is not None:
            cols = _frame.where(cols, where)
        if columns is not None:
            missing = set(columns) - cols.keys()
            if missing:
                raise SchemaError(f"missing columns {sorted(missing)}")
            cols = {k: cols[k] for k in columns}
        return cols

    def logical_digest(self, digest: str) -> str:
        """Fingerprint of the table's LOGICAL contents: schema + each
        column's row bytes concatenated in row order, independent of how
        rows are fragmented into files or manifests.  Two snapshots with
        the same logical digest hold bit-identical tables — the proof
        obligation compaction discharges (``core/compact.py``)."""
        snap = self.load_snapshot(digest)
        names = snap.schema.names()
        hashers = {name: hashlib.sha256() for name in names}
        for frame in self.iter_files(digest):
            for name in names:
                hashers[name].update(
                    np.ascontiguousarray(frame[name]).tobytes())
        acc = hashlib.sha256(_pack(snap.schema.to_obj()))
        for name in names:
            acc.update(name.encode("utf-8"))
            acc.update(hashers[name].digest())
        return acc.hexdigest()

    def history(self, digest: str) -> List[str]:
        """Snapshot lineage, newest first (time travel within one table)."""
        out, cur = [], digest
        while cur is not None:
            out.append(cur)
            cur = self.load_snapshot(cur).parent
        return out


def _row_chunks(cols: Mapping[str, np.ndarray], rows_per_file: int):
    arrays = {k: np.asarray(v) for k, v in cols.items()}
    if not arrays:
        raise SchemaError("no columns")
    n = next(iter(arrays.values())).shape[0]
    if n == 0:
        raise SchemaError("empty columns")
    for start in range(0, n, rows_per_file):
        stop = min(start + rows_per_file, n)
        yield {k: v[start:stop] for k, v in arrays.items()}
