"""Content-addressed object store with S3-like key layout.

This is the "S3" of the paper (Table 1, Fig. 2/3): every artifact — tensor
files, table snapshots, commits, run manifests — is an immutable blob keyed by
the sha-256 of its *uncompressed* content.  Immutability + content addressing
is what makes branches copy-on-write and runs replayable.

The filesystem backend mirrors an S3 key scheme (``objects/ab/cdef...``) so a
real S3/GCS backend is a drop-in replacement of this one class.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import threading
from pathlib import Path
from typing import Iterator, Optional

import zstandard as zstd

from .errors import ObjectNotFound, RefConflict, RefNotFound

_MAGIC = b"RPR1"  # blob framing: magic + 1 byte codec id
_CODEC_RAW = b"\x00"
_CODEC_ZSTD = b"\x01"


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


class ObjectStore:
    """Immutable content-addressed blobs + mutable atomic refs.

    Objects:  ``put(bytes) -> digest``; ``get(digest) -> bytes``.
    Refs:     ``set_ref/get_ref/cas_ref`` — tiny mutable pointers used only by
              the catalog for branch heads (everything else is immutable).
    """

    def __init__(self, root: str | os.PathLike, *, compress: bool = True,
                 level: int = 3):
        self.root = Path(root)
        self.obj_dir = self.root / "objects"
        self.ref_dir = self.root / "refs"
        self.obj_dir.mkdir(parents=True, exist_ok=True)
        self.ref_dir.mkdir(parents=True, exist_ok=True)
        self.compress = compress
        self._cctx = zstd.ZstdCompressor(level=level)
        self._dctx = zstd.ZstdDecompressor()
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ blobs
    def _path(self, digest: str) -> Path:
        return self.obj_dir / digest[:2] / digest[2:]

    def put(self, data: bytes) -> str:
        digest = sha256_hex(data)
        path = self._path(digest)
        if path.exists():  # dedup: content addressing makes re-puts free
            return digest
        payload = (
            _MAGIC + _CODEC_ZSTD + self._cctx.compress(data)
            if self.compress and len(data) > 64
            else _MAGIC + _CODEC_RAW + data
        )
        path.parent.mkdir(parents=True, exist_ok=True)
        # Write-then-rename so readers never observe partial objects.
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return digest

    def get(self, digest: str) -> bytes:
        path = self._path(digest)
        try:
            payload = path.read_bytes()
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None
        if payload[:4] != _MAGIC:
            raise ObjectNotFound(f"corrupt object {digest}")
        codec, body = payload[4:5], payload[5:]
        data = self._dctx.decompress(body) if codec == _CODEC_ZSTD else body
        if sha256_hex(data) != digest:
            raise ObjectNotFound(f"digest mismatch for {digest}")
        return data

    def has(self, digest: str) -> bool:
        return self._path(digest).exists()

    def size(self, digest: str) -> int:
        """On-disk (compressed) size — used by benchmarks."""
        try:
            return self._path(digest).stat().st_size
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None

    def iter_objects(self) -> Iterator[str]:
        for sub in sorted(self.obj_dir.iterdir()):
            if not sub.is_dir():
                continue
            for obj in sorted(sub.iterdir()):
                if not obj.name.startswith("."):
                    yield sub.name + obj.name

    # ------------------------------------------------------------------- refs
    def _ref_path(self, name: str) -> Path:
        if "/" in name or name.startswith("."):
            raise ValueError(f"bad ref name {name!r}")
        return self.ref_dir / name

    def set_ref(self, name: str, digest: str) -> None:
        path = self._ref_path(name)
        fd, tmp = tempfile.mkstemp(dir=self.ref_dir, prefix=".tmp-")
        with os.fdopen(fd, "w") as f:
            f.write(digest)
        os.replace(tmp, path)

    def get_ref(self, name: str) -> str:
        try:
            return self._ref_path(name).read_text().strip()
        except FileNotFoundError:
            raise RefNotFound(name) from None

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        """Compare-and-set a ref (atomicity of catalog commits)."""
        with self._lock:
            current: Optional[str]
            try:
                current = self.get_ref(name)
            except RefNotFound:
                current = None
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r}")
            self.set_ref(name, new)

    def delete_ref(self, name: str) -> None:
        try:
            self._ref_path(name).unlink()
        except FileNotFoundError:
            raise RefNotFound(name) from None

    def iter_refs(self) -> Iterator[str]:
        for p in sorted(self.ref_dir.iterdir()):
            if not p.name.startswith("."):
                yield p.name
