"""Content-addressed object store with S3-like key layout.

This is the "S3" of the paper (Table 1, Fig. 2/3): every artifact — tensor
files, table snapshots, commits, run manifests — is an immutable blob keyed by
the sha-256 of its *uncompressed* content.  Immutability + content addressing
is what makes branches copy-on-write and runs replayable.

The filesystem backend mirrors an S3 key scheme (``objects/ab/cdef...``) so a
real S3/GCS backend is a drop-in replacement of this one class.

Compression is pluggable per-blob via a codec byte in the framing, so a store
written with zstd stays readable on a host that only has the stdlib: zstd is
preferred when the ``zstandard`` package is importable, with a zlib fallback
otherwise (distinct codec byte — old blobs keep decoding either way).

Refs come in two layouts:

    flat:        ``branch=main``, ``tag=v1.0``, ``runs-head``
    namespaced:  ``cache/ab/cdef...`` — "/"-separated segments map to
                 subdirectories, used by the run cache so its (potentially
                 many) entries shard like objects do
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import threading
import zlib
from contextlib import contextmanager
from pathlib import Path
from typing import (Dict, Iterable, Iterator, List, Optional, Protocol,
                    Sequence, Set, Tuple, runtime_checkable)

try:  # optional: preferred codec when available
    import zstandard as zstd
except ImportError:  # pragma: no cover - exercised on the no-zstd CI leg
    zstd = None

try:  # POSIX file locking for cross-process CAS; absent on Windows
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX fallback: thread lock only
    fcntl = None

from .errors import (AmbiguousRefUpdate, CodecUnavailable, ObjectNotFound,
                     RefConflict, RefNotFound)

_MAGIC = b"RPR1"  # blob framing: magic + 1 byte codec id
_CODEC_RAW = b"\x00"
_CODEC_ZSTD = b"\x01"
_CODEC_ZLIB = b"\x02"

#: GC generation token, stored in the refs keyspace so every backend can
#: CAS it.  A sweep bumps it (monotone integer, as text) BEFORE marking;
#: an in-flight push/pull captures it at transfer start and validates it
#: inside its final ``cas_refs`` batch — a push that raced a sweep fails
#: the ref update cleanly and re-uploads instead of publishing refs to
#: deleted blobs (docs/remote_store.md, "Concurrent-safe remote GC").
GC_GENERATION_REF = "gc/generation"

#: codecs this build can *write* ("auto" = best available compressor)
WRITE_CODECS = ("auto", "raw", "zlib") + (("zstd",) if zstd else ())


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def frame_raw(data: bytes) -> bytes:
    """Frame ``data`` uncompressed (magic + raw codec byte).  The shape a
    backend without a compressor hands out for encoded transfers."""
    return _MAGIC + _CODEC_RAW + data


def encode_frame(data: bytes, *, codec: str = "auto", level: int = 3) -> bytes:
    """Frame (and compress) raw content bytes the way the store does at
    rest — the encoder counterpart of :func:`decode_frame`, for backends
    that keep blobs in framed form off-disk (the S3 keyspace)."""
    if codec == "auto":
        codec = "zstd" if zstd is not None else "zlib"
    if len(data) <= 64 or codec == "raw":
        return _MAGIC + _CODEC_RAW + data
    if codec == "zstd":
        if zstd is None:
            raise ValueError("codec='zstd' but zstandard is not installed")
        return _MAGIC + _CODEC_ZSTD + zstd.ZstdCompressor(
            level=level).compress(data)
    return _MAGIC + _CODEC_ZLIB + zlib.compress(data, min(level, 9))


def decode_frame(payload: bytes, *, what: str = "object") -> bytes:
    """Decode one framed blob payload back to its raw content bytes.

    The inverse of the store's at-rest framing, shared by every consumer of
    *encoded* blobs (the on-disk payloads, compressed wire frames, the S3
    keyspace): magic check, codec dispatch, decompress.  Raises
    :class:`CodecUnavailable` when the payload needs a compressor this host
    does not have (zstd payload, no zstandard package) so transfer paths
    can fall back to raw blobs instead of failing the whole operation."""
    if payload[:4] != _MAGIC:
        raise ObjectNotFound(f"corrupt {what}: bad frame magic")
    codec, body = payload[4:5], payload[5:]
    if codec == _CODEC_RAW:
        return body
    if codec == _CODEC_ZLIB:
        return zlib.decompress(body)
    if codec == _CODEC_ZSTD:
        if zstd is None:
            raise CodecUnavailable(
                f"{what} is zstd-compressed but the zstandard package "
                "is not installed")
        return zstd.ZstdDecompressor().decompress(body)
    raise ObjectNotFound(f"unknown codec {codec!r} for {what}")


@runtime_checkable
class StoreBackend(Protocol):
    """The object-store wire contract every backend speaks.

    Extracted from the filesystem :class:`ObjectStore` so that a remote
    backend (:class:`repro.core.remote.RemoteStore`), a tiered composite
    (:class:`repro.core.remote.TieredStore`), or a real S3/GCS client is a
    drop-in replacement anywhere a store is accepted (catalog, run cache,
    ledger, table IO, sync).  Semantics every implementation must honor:

    * **objects** are immutable and content addressed — ``put`` is
      idempotent, ``get`` verifies the digest, partially written objects are
      never observable;
    * **refs** are tiny mutable pointers with atomic ``cas_ref``
      (linearizable per ref name) and all-or-nothing ``cas_refs`` across
      several names (the multi-ref push contract);
    * **listing** is paged and sorted so closure transfers can resume;
    * **exists** checks batch (``has_many``) and blob reads/writes batch
      (``get_many``/``put_many``) so transfers can dedup and pipeline
      without a round-trip per object.

    Backends may additionally implement the **optional delta capability**
    — ``has_chunks(hashes) -> Set[str]`` and
    ``put_objects_delta(items) -> (stored, stale)`` over content-defined
    chunk recipes (see :mod:`repro.core.delta`).  The sync engine probes
    for these with ``hasattr`` and degrades to whole-frame transfer when
    absent, so the methods are deliberately NOT part of this protocol:
    implementing them is a bandwidth optimization, never a correctness
    requirement.
    """

    # objects -----------------------------------------------------------
    def put(self, data: bytes) -> str: ...
    def get(self, digest: str) -> bytes: ...
    def has(self, digest: str) -> bool: ...
    def has_many(self, digests: Iterable[str]) -> Set[str]: ...
    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]: ...
    def put_many(self, blobs: Sequence[bytes]) -> List[str]: ...
    def size(self, digest: str) -> int: ...
    # upload age (seconds-since-epoch mtime of the stored payload): what
    # the GC grace window compares against — fs via stat, S3 via the
    # Last-Modified header, the wire via the stat_object op
    def mtime(self, digest: str) -> float: ...
    # combined (size, mtime) in ONE backend round-trip — what the sweep
    # uses per candidate so a remote collection never pays two
    def stat(self, digest: str) -> Tuple[int, float]: ...
    # best-effort mtime refresh of already-present objects (the sync
    # engine's touch-on-dedup): returns how many were actually touched —
    # 0 is a valid answer for backends with no cheap touch (S3), the GC
    # generation-retry path still protects those
    def touch_many(self, digests: Sequence[str]) -> int: ...
    def delete_object(self, digest: str) -> bool: ...
    # encoded (framed, possibly compressed) payload transfer: a blob
    # compressed once at rest crosses every hop in that form — see
    # ``decode_frame`` for the framing and docs/remote_store.md for the
    # wire-frame compression contract
    def get_encoded(self, digest: str) -> bytes: ...
    def put_encoded(self, payload: bytes) -> str: ...
    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]: ...
    # ``digests`` is an optional hint from a caller that already decoded
    # and digest-verified the payloads (the transfer engine does, for
    # accounting): backends whose far side re-verifies anyway may use it
    # to skip a redundant local decode
    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]: ...
    def iter_objects(self) -> Iterator[str]: ...
    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000
                     ) -> Tuple[List[str], Optional[str]]: ...

    # refs --------------------------------------------------------------
    def set_ref(self, name: str, digest: str) -> None: ...
    def get_ref(self, name: str) -> str: ...
    def cas_ref(self, name: str, expected: Optional[str],
                new: str) -> None: ...
    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None: ...
    def delete_ref(self, name: str) -> None: ...
    def iter_refs(self, prefix: str = "") -> Iterator[str]: ...
    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]: ...


def read_generation(store: "StoreBackend") -> Optional[str]:
    """Current GC generation token of ``store`` (None = no sweep ever ran
    and nobody materialized the ref yet)."""
    try:
        return store.get_ref(GC_GENERATION_REF)
    except RefNotFound:
        return None


def ensure_generation(store: "StoreBackend") -> str:
    """Read the generation token, materializing ``"0"`` if absent — so a
    sync can always include an exact-value guard in its ``cas_refs`` batch
    (guarding on "absent" would make two concurrent first pushes conflict
    with each other instead of only with sweeps).  An ambiguous wire CAS
    (the materializing write may or may not have landed) resolves itself
    through the re-read at the top of the next attempt."""
    last: Optional[Exception] = None
    for _ in range(4):
        current = read_generation(store)
        if current is not None:
            return current
        try:
            store.cas_ref(GC_GENERATION_REF, None, "0")
            return "0"
        except (RefConflict, AmbiguousRefUpdate) as e:
            last = e  # racer / unknown delivery — the re-read decides
    raise RefConflict(
        f"could not materialize {GC_GENERATION_REF!r}") from last


def read_ref_or_none(store: "StoreBackend", name: str) -> Optional[str]:
    """``get_ref`` with the miss folded into the value (None = absent) —
    the read half of every CAS loop over coordination refs (GC generation,
    executor leases)."""
    try:
        return store.get_ref(name)
    except RefNotFound:
        return None


def try_cas_ref(store: "StoreBackend", name: str, expected: Optional[str],
                new: str) -> bool:
    """One CAS attempt as a boolean: True iff ``name`` moved from
    ``expected`` to ``new``.

    The primitive the executor's lease machinery is built on (claim,
    heartbeat, complete are all single-ref CAS transitions, exactly like
    the GC generation token): a clean :class:`RefConflict` is a lost race
    (False, the caller re-reads), and an :class:`AmbiguousRefUpdate` —
    a transport fault after the request may have been delivered — is
    resolved by re-reading: lease values embed owner + deadline, so
    observing our exact value means our write landed."""
    try:
        store.cas_ref(name, expected, new)
        return True
    except RefConflict:
        return False
    except AmbiguousRefUpdate:
        return read_ref_or_none(store, name) == new


def bump_generation(store: "StoreBackend") -> str:
    """Advance the GC generation token (CAS loop, any backend).  Called at
    sweep START, before the mark phase reads refs: any sync that captured
    the previous token — i.e. any sync whose uploads could predate the
    mark — fails its ref update cleanly and retries, instead of publishing
    refs to objects the sweep is about to delete."""
    last: Optional[Exception] = None
    for _ in range(16):
        current = read_generation(store)
        nxt = str(int(current) + 1) if current is not None else "1"
        try:
            store.cas_ref(GC_GENERATION_REF, current, nxt)
            return nxt
        except RefConflict as e:
            last = e  # concurrent bump/materialize — re-read and retry
        except AmbiguousRefUpdate as e:
            # the bump may have landed before the fault: a re-read showing
            # our exact value claims it (any OTHER change restarts — some
            # concurrent bump won, and a sweep must own a fresh token)
            if read_generation(store) == nxt:
                return nxt
            last = e
    raise RefConflict(
        f"could not advance {GC_GENERATION_REF!r} "
        "(persistent contention or transport faults)") from last


class ObjectStore:
    """Immutable content-addressed blobs + mutable atomic refs.

    Objects:  ``put(bytes) -> digest``; ``get(digest) -> bytes``.
    Refs:     ``set_ref/get_ref/cas_ref`` — tiny mutable pointers used only by
              the catalog for branch heads and the run cache for cache keys
              (everything else is immutable).
    """

    def __init__(self, root: str | os.PathLike, *, compress: bool = True,
                 level: int = 3, codec: str = "auto"):
        self.root = Path(root)
        self.obj_dir = self.root / "objects"
        self.ref_dir = self.root / "refs"
        self.obj_dir.mkdir(parents=True, exist_ok=True)
        self.ref_dir.mkdir(parents=True, exist_ok=True)
        self.compress = compress
        if codec not in ("auto", "raw", "zlib", "zstd"):
            raise ValueError(f"unknown codec {codec!r}")
        if codec == "zstd" and zstd is None:
            raise ValueError("codec='zstd' but zstandard is not installed")
        if codec == "auto":
            codec = "zstd" if zstd is not None else "zlib"
        self.codec = codec
        self.level = level
        if zstd is not None:
            self._cctx = zstd.ZstdCompressor(level=level)
            self._dctx = zstd.ZstdDecompressor()
        else:
            self._cctx = self._dctx = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ blobs
    def _path(self, digest: str) -> Path:
        return self.obj_dir / digest[:2] / digest[2:]

    def _encode(self, data: bytes) -> bytes:
        if not self.compress or len(data) <= 64 or self.codec == "raw":
            return _MAGIC + _CODEC_RAW + data
        if self.codec == "zstd":
            return _MAGIC + _CODEC_ZSTD + self._cctx.compress(data)
        # zstd levels reach 22 but zlib's cap is 9 — clamp so a store tuned
        # for zstd keeps working on a host that falls back to zlib
        return _MAGIC + _CODEC_ZLIB + zlib.compress(data, min(self.level, 9))

    def _decode(self, digest: str, payload: bytes) -> bytes:
        if payload[4:5] == _CODEC_ZSTD and self._dctx is not None:
            # hot path: reuse this store's decompressor across reads
            if payload[:4] != _MAGIC:
                raise ObjectNotFound(f"corrupt object {digest}")
            return self._dctx.decompress(payload[5:])
        return decode_frame(payload, what=f"object {digest}")

    def put(self, data: bytes) -> str:
        digest = sha256_hex(data)
        path = self._path(digest)
        if path.exists():  # dedup: content addressing makes re-puts free
            return digest
        payload = self._encode(data)
        path.parent.mkdir(parents=True, exist_ok=True)
        # Write-then-rename so readers never observe partial objects.
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return digest

    def get(self, digest: str) -> bytes:
        path = self._path(digest)
        try:
            payload = path.read_bytes()
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None
        data = self._decode(digest, payload)
        if sha256_hex(data) != digest:
            raise ObjectNotFound(f"digest mismatch for {digest}")
        return data

    def has(self, digest: str) -> bool:
        return self._path(digest).exists()

    def has_many(self, digests: Iterable[str]) -> Set[str]:
        """Subset of ``digests`` present in the store (batched exists —
        one call per transfer chunk instead of one round-trip per object)."""
        return {d for d in digests if self.has(d)}

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        """Batched read.  Local disk gains nothing from batching, but the
        remote backends do — this keeps the wire contract uniform so the
        transfer engine can pipeline leaf blobs in chunks everywhere."""
        return {d: self.get(d) for d in digests}

    def put_many(self, blobs: Sequence[bytes]) -> List[str]:
        """Batched write, digests returned in input order."""
        return [self.put(b) for b in blobs]

    def delete_object(self, digest: str) -> bool:
        """Remove one object (GC sweep).  Idempotent: missing → False."""
        try:
            self._path(digest).unlink()
            return True
        except FileNotFoundError:
            return False

    def touch_many(self, digests: Sequence[str]) -> int:
        """Reset present objects' mtimes to now; returns how many existed.

        The sync engine calls this on dedup hits so a long push can't have
        its already-present objects age past the GC grace window while the
        rest of the closure is still uploading (the ref flip that would
        protect them only lands at the end)."""
        touched = 0
        for digest in digests:
            try:
                os.utime(self._path(digest))
                touched += 1
            except FileNotFoundError:
                continue  # raced a sweep: the generation token catches it
        return touched

    # ------------------------------------------------- encoded payloads
    def get_encoded(self, digest: str) -> bytes:
        """The object's framed at-rest payload, compression and all.

        What compressed wire frames carry: a blob pays for compression
        once (at ``put``) and crosses every subsequent hop in that form.
        The receiver (:meth:`put_encoded`) decodes and digest-verifies, so
        handing out the payload un-reverified is safe."""
        try:
            payload = self._path(digest).read_bytes()
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None
        if payload[:4] != _MAGIC:
            raise ObjectNotFound(f"corrupt object {digest}")
        return payload

    def put_encoded(self, payload: bytes) -> str:
        """Store a framed payload as-is (no recompression): decode to
        verify the content digest, then land the original payload under
        it.  Raises :class:`~repro.core.errors.CodecUnavailable` when the
        payload's codec cannot be decoded here — callers fall back to raw
        transfer (the sender re-sends uncompressed)."""
        data = decode_frame(payload, what="encoded payload")
        digest = sha256_hex(data)
        path = self._path(digest)
        if path.exists():
            return digest
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return digest

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        return {d: self.get_encoded(d) for d in digests}

    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]:
        # the digest hint is ignored here: this store is where the payload
        # comes to rest, so it always decodes and verifies for itself
        return [self.put_encoded(p) for p in payloads]

    def size(self, digest: str) -> int:
        """On-disk (compressed) size — used by benchmarks."""
        try:
            return self._path(digest).stat().st_size
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None

    def mtime(self, digest: str) -> float:
        """When the object landed here (write-then-rename publish time).
        The GC grace window keys off this: a sweep never deletes an object
        younger than ``prune_age``, so an in-flight push's uploads are
        safe even before its refs move."""
        return self.stat(digest)[1]

    def stat(self, digest: str) -> Tuple[int, float]:
        """``(on-disk size, mtime)`` from one os.stat."""
        try:
            st = self._path(digest).stat()
        except FileNotFoundError:
            raise ObjectNotFound(digest) from None
        return st.st_size, st.st_mtime

    def iter_objects(self) -> Iterator[str]:
        for sub in sorted(self.obj_dir.iterdir()):
            if not sub.is_dir():
                continue
            for obj in sorted(sub.iterdir()):
                if not obj.name.startswith("."):
                    yield sub.name + obj.name

    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000
                     ) -> Tuple[List[str], Optional[str]]:
        """One page of object digests in sorted order.

        ``page_token`` is the last digest of the previous page (exclusive
        resume point — the same shape as S3 ListObjectsV2 continuation
        tokens over the ``objects/ab/cdef...`` key layout)."""
        limit = max(1, limit)
        page: List[str] = []
        for digest in self.iter_objects():
            if page_token is not None and digest <= page_token:
                continue
            page.append(digest)
            if len(page) >= limit:
                return page, digest
        return page, None

    # ------------------------------------------------------------------- refs
    def _ref_path(self, name: str) -> Path:
        parts = name.split("/")
        for part in parts:
            if not part or part.startswith("."):
                raise ValueError(f"bad ref name {name!r}")
        return self.ref_dir.joinpath(*parts)

    def set_ref(self, name: str, digest: str) -> None:
        path = self._ref_path(name)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        with os.fdopen(fd, "w") as f:
            f.write(digest)
        os.replace(tmp, path)

    def get_ref(self, name: str) -> str:
        try:
            return self._ref_path(name).read_text().strip()
        except FileNotFoundError:
            raise RefNotFound(name) from None

    @contextmanager
    def ref_guard(self):
        """Exclusive critical section over this store's refs, across
        threads, instances AND processes (exclusive ``flock`` on a sidecar
        lock file).  ``cas_ref`` runs inside it; composites like
        ``TieredStore`` borrow it so their read-compare-write against a
        merged ref view stays linearizable too.  Not reentrant."""
        with self._lock, open(self.ref_dir / ".cas-lock", "w") as lockf:
            if fcntl is not None:
                fcntl.flock(lockf, fcntl.LOCK_EX)  # released on close
            yield

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        """Compare-and-set a ref (atomicity of catalog commits).

        Linearizable across *instances and processes* sharing one store
        directory, not just threads of one instance — two servers fronting
        the same tree cannot both win a race (the contract ``RemoteStore``
        clients and push's ref handoff rely on)."""
        with self.ref_guard():
            current: Optional[str]
            try:
                current = self.get_ref(name)
            except RefNotFound:
                current = None
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r}")
            self.set_ref(name, new)

    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None:
        """All-or-nothing compare-and-set across several refs.

        Every expectation is validated inside ONE ref-guard critical section
        before any ref moves, so a single stale expectation leaves every ref
        untouched — the atomicity contract a multi-ref push rides on (one
        conflicting branch rolls back the entire ref update).  Same
        cross-thread/-instance/-process linearizability as ``cas_ref``."""
        with self.ref_guard():
            for name, expected, _new in updates:
                try:
                    current: Optional[str] = self.get_ref(name)
                except RefNotFound:
                    current = None
                if current != expected:
                    raise RefConflict(
                        f"ref {name}: expected {expected!r}, found "
                        f"{current!r} (no ref in this batch was updated)")
            for name, _expected, new in updates:
                self.set_ref(name, new)

    def delete_ref(self, name: str) -> None:
        try:
            self._ref_path(name).unlink()
        except FileNotFoundError:
            raise RefNotFound(name) from None

    def iter_refs(self, prefix: str = "") -> Iterator[str]:
        """All ref names (namespaced refs as ``ns/sub/name``), sorted."""
        names = []
        for dirpath, dirnames, filenames in os.walk(self.ref_dir):
            dirnames[:] = sorted(d for d in dirnames if not d.startswith("."))
            rel = Path(dirpath).relative_to(self.ref_dir)
            for fn in filenames:
                if fn.startswith("."):
                    continue
                name = fn if rel == Path(".") else (rel / fn).as_posix()
                if name.startswith(prefix):
                    names.append(name)
        yield from sorted(names)

    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]:
        """One page of ``(name, digest)`` pairs in sorted name order.

        Returning the value with the name saves the per-ref ``get_ref``
        round-trip a remote sync would otherwise pay.  Refs deleted between
        the directory walk and the read are skipped (no torn pages)."""
        limit = max(1, limit)
        page: List[Tuple[str, str]] = []
        last: Optional[str] = None
        for name in self.iter_refs(prefix):
            if page_token is not None and name <= page_token:
                continue
            try:
                page.append((name, self.get_ref(name)))
            except RefNotFound:  # concurrently deleted
                continue
            last = name
            if len(page) >= limit:
                return page, last
        return page, None
