"""Incremental run cache: memoized node outputs keyed by (code, data, params).

The paper's pain point is that pipeline size makes testing/iteration slow; its
answer is replayable runs pinned by (code version, data commit).  The run
cache turns that pin into a *speedup*: a node whose code hash, input snapshot
digests and injected params are all unchanged can return its previous output
snapshot without executing — replaying a pipeline on an unchanged branch is a
pure cache lookup, and editing one node re-runs only its downstream cone
(the edited node's output digest changes, which changes every descendant's
cache key).

Layout (on top of :class:`~repro.core.store.ObjectStore`):

    ref   cache/<k0k1>/<k2..>   ->  entry blob digest      (mutable pointer)
    blob  <entry digest>        ->  msgpack {node, snapshot, code_hash,
                                             inputs, ts}   (immutable)

Cache keys are sha-256 over a canonical msgpack encoding, so they are stable
across processes and hosts.  The entry is only honored when its output
snapshot is still present in the store (GC-safe: a swept snapshot simply
turns the entry into a miss).
"""

from __future__ import annotations

import hashlib
import time
from typing import (Any, Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple)

import msgpack
import numpy as np

from .errors import ObjectNotFound, RefNotFound
from .store import ObjectStore

#: ref namespace for cache entries (sharded like objects: cache/ab/cdef...)
CACHE_REF_PREFIX = "cache/"


class CacheDemotionWarning(UserWarning):
    """A node was silently demoted to uncacheable at run time: one of its
    injected params has no stable cache encoding (``_canon_value`` raised
    TypeError).  The node still runs — every time — but warm replays will
    never hit for it.  Surfaced once per node per process so a pipeline that
    quietly lost its incrementality shows up in the first run's warnings
    instead of in a profiler."""


def _canon_value(v: Any) -> str:
    """Canonical string for one param value.  Arrays are hashed over their
    raw bytes — ``repr`` truncates large arrays ("[0., 1., ..., 9999.]"), so
    two different arrays could collide on one key and serve a stale
    snapshot.  Containers recurse; scalars keep their full repr.  Arbitrary
    objects raise TypeError: an opaque ``__repr__`` either hides state (two
    distinct configs collide) or embeds an address (the key never repeats) —
    the executor degrades such nodes to uncacheable instead."""
    if isinstance(v, (bool, int, float, str, bytes, type(None))):
        return repr(v)
    if isinstance(v, np.ndarray):
        data = np.ascontiguousarray(v)
        return (f"ndarray:{data.dtype.str}:{data.shape}:"
                f"{hashlib.sha256(data.tobytes()).hexdigest()}")
    if isinstance(v, (list, tuple)):
        inner = ",".join(_canon_value(x) for x in v)
        return f"{type(v).__name__}:[{inner}]"
    if isinstance(v, dict):
        inner = ",".join(f"{k!r}:{_canon_value(v[k])}" for k in sorted(v))
        return f"dict:{{{inner}}}"
    if isinstance(v, np.generic):  # numpy scalar: dtype matters
        return f"npscalar:{v.dtype.str}:{v!r}"
    raise TypeError(
        f"param value of type {type(v).__name__!r} has no stable cache "
        "encoding (use scalars, arrays, or containers thereof)")


def _canonical_params(params: Mapping[str, Any]) -> List[Tuple[str, str]]:
    return [(k, _canon_value(params[k])) for k in sorted(params)]


def node_key(code_hash: str,
             input_digests: Sequence[Tuple[str, str]],
             params: Optional[Mapping[str, Any]] = None,
             *, name: str = "") -> str:
    """Cache key of one node: (node name, code hash, sorted input snapshot
    digests, injected params).  ``input_digests`` is (dep name, snapshot
    digest) pairs; sorting makes the key independent of declaration order.
    The name disambiguates factory-built nodes whose source text coincides."""
    material = msgpack.packb(
        {
            "v": 1,
            "name": name,
            "code": code_hash,
            "inputs": sorted((str(n), str(d)) for n, d in input_digests),
            "params": _canonical_params(params or {}),
        },
        use_bin_type=True,
    )
    return hashlib.sha256(material).hexdigest()


class RunCache:
    """Node-output memo table backed by the object store.

    Entries are refs (so they are cheap to overwrite/invalidate) pointing at
    immutable entry blobs; the blobs and the referenced output snapshots are
    GC roots while the ref exists (see ``gc.collect``).
    """

    def __init__(self, store: ObjectStore, *, clock=time.time):
        self.store = store
        self.clock = clock

    @staticmethod
    def _ref(key: str) -> str:
        return f"{CACHE_REF_PREFIX}{key[:2]}/{key[2:]}"

    # ----------------------------------------------------------------- lookup
    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Entry dict for ``key``, or None on miss / stale snapshot."""
        try:
            entry_digest = self.store.get_ref(self._ref(key))
        except RefNotFound:
            return None
        try:
            entry = msgpack.unpackb(self.store.get(entry_digest), raw=False)
        except ObjectNotFound:
            return None
        snapshot = entry.get("snapshot")
        if not snapshot or not self.store.has(snapshot):
            return None  # output was GC'd — treat as a miss
        return entry

    # ------------------------------------------------------------------ store
    def put(self, key: str, *, node: str, snapshot: str, code_hash: str,
            inputs: Sequence[Tuple[str, str]]) -> None:
        entry = {
            "node": node,
            "snapshot": snapshot,
            "code_hash": code_hash,
            "inputs": sorted((str(n), str(d)) for n, d in inputs),
            "ts": self.clock(),
        }
        digest = self.store.put(msgpack.packb(entry, use_bin_type=True))
        self.store.set_ref(self._ref(key), digest)

    # --------------------------------------------------------------- transfer
    @staticmethod
    def key_of_ref(ref_name: str) -> str:
        """Cache key encoded in a ``cache/ab/cdef...`` ref name."""
        return ref_name[len(CACHE_REF_PREFIX):].replace("/", "", 1)

    def entry_refs(self) -> Iterator[Tuple[str, str]]:
        """All ``(key, entry blob digest)`` pairs, paged under the hood —
        what push/pull enumerate to compute the run-cache closure."""
        token: Optional[str] = None
        while True:
            page, token = self.store.list_refs(CACHE_REF_PREFIX,
                                               page_token=token, limit=500)
            for name, digest in page:
                yield self.key_of_ref(name), digest
            if token is None:
                return

    def adopt(self, key: str, entry_digest: str) -> bool:
        """Point ``key`` at an entry blob transferred from another store.
        Returns False when the key already holds that exact entry."""
        ref = self._ref(key)
        try:
            if self.store.get_ref(ref) == entry_digest:
                return False
        except RefNotFound:
            pass
        self.store.set_ref(ref, entry_digest)
        return True

    # ------------------------------------------------------------- management
    def invalidate(self, key: str) -> bool:
        try:
            self.store.delete_ref(self._ref(key))
            return True
        except RefNotFound:
            return False

    def keys(self) -> List[str]:
        return [r[len(CACHE_REF_PREFIX):].replace("/", "", 1)
                for r in self.store.iter_refs(CACHE_REF_PREFIX)]

    def clear(self) -> int:
        """Drop every cache entry (the blobs become GC-collectable)."""
        n = 0
        for ref in list(self.store.iter_refs(CACHE_REF_PREFIX)):
            try:
                self.store.delete_ref(ref)
                n += 1
            except RefNotFound:  # concurrent clear
                pass
        return n

    def __len__(self) -> int:
        return sum(1 for _ in self.store.iter_refs(CACHE_REF_PREFIX))
