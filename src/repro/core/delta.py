"""Delta frames: content-defined chunking so a push resends only what changed.

Checkpoint-to-checkpoint pushes are the motivating workload (docs/tables.md):
two successive weight snapshots usually share almost all of their bytes, but
any single changed element gives the tensorfile a new content digest — so
blob-level dedup (``has_many``) sees a brand-new object and ships the whole
thing.  Delta frames recover the sharing *inside* a blob:

1. the sender splits the raw content into **content-defined chunks** — cut
   points chosen by a rolling hash of a small byte window, so an insert or
   edit only disturbs the chunks it touches and the cut points re-synchronize
   right after (a fixed-size grid would shift every boundary downstream);
2. one ``has_chunks`` round-trip asks the receiver which chunk hashes it
   already holds (the receiver keeps a bounded :class:`ChunkIndex` over the
   large blobs it has seen arrive);
3. the blob crosses the wire as a **recipe** — literal runs for missing
   chunks, ``(chunk hash)`` references for present ones — and the receiver
   reassembles, re-hashes every referenced chunk, verifies the whole blob's
   digest, and stores it like any other put.

Everything here is deterministic (the gear table is derived from sha-256 of
fixed strings, never from process randomness), so two hosts always agree on
chunk boundaries — the property the hypothesis suite in
``tests/test_delta_frames.py`` pins, along with bit-identical reassembly
under random insert/delete/edit mutations.

The wire ops live in :mod:`repro.core.remote` (``has_chunks`` /
``put_objects_delta``) and are negotiated per hop: a server that predates
them answers "unknown op" once and the sender downgrades to whole-frame
transfer for the rest of the sync (same pattern as the encoded-payload ops).
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import (Callable, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from .errors import ObjectNotFound

#: default chunking geometry.  ``avg`` must be a power of two (the cut
#: condition masks the rolling hash with ``avg - 1``); expected chunk size
#: is roughly ``min + avg``.  Shared by sender and receiver so the index
#: built on arrival matches the boundaries the next push computes.
MIN_CHUNK = 2048
AVG_CHUNK = 8192
MAX_CHUNK = 65536

#: blobs below this raw size are never chunked/delta'd — the recipe and
#: has_chunks overhead would exceed the possible saving
DELTA_MIN_BYTES = 32768

_WINDOW = 48  # rolling-hash window: edits further apart than this re-sync

#: per-op wire overhead charged for a chunk reference in recipe accounting
#: (64-hex hash + msgpack framing); literal runs are charged at byte length
REF_WIRE_COST = 72


def _gear_table() -> "np.ndarray":
    """256 pseudo-random 64-bit values, derived deterministically so every
    host computes identical cut points."""
    table = np.empty(256, dtype=np.uint64)
    for i in range(256):
        digest = hashlib.sha256(b"repro-delta-gear-%d" % i).digest()
        table[i] = int.from_bytes(digest[:8], "big")
    return table


_GEAR = _gear_table()


def chunk_spans(data: bytes, *, min_size: int = MIN_CHUNK,
                avg_size: int = AVG_CHUNK,
                max_size: int = MAX_CHUNK) -> List[Tuple[int, int]]:
    """Content-defined ``(offset, length)`` partition of ``data``.

    A position is a candidate cut when the windowed rolling hash of the
    preceding ``_WINDOW`` bytes lands on zero under the ``avg_size - 1``
    mask (so cuts depend only on nearby content, giving ~1 cut per
    ``avg_size`` bytes); candidates closer than ``min_size`` to the
    previous cut are skipped and runs longer than ``max_size`` are force-
    cut on a fixed grid.  The spans are contiguous and cover ``data``
    exactly — reassembly by concatenation is the identity."""
    n = len(data)
    if n == 0:
        return []
    if avg_size & (avg_size - 1):
        raise ValueError(f"avg_size must be a power of two, got {avg_size}")
    if n <= min_size or n <= _WINDOW:
        return [(0, n)]
    mapped = _GEAR[np.frombuffer(data, dtype=np.uint8)]
    csum = np.cumsum(mapped, dtype=np.uint64)  # wraps mod 2**64, by design
    rolling = csum[_WINDOW:] - csum[:-_WINDOW]
    mask = np.uint64(avg_size - 1)
    candidates = (np.nonzero((rolling & mask) == 0)[0] + _WINDOW).tolist()
    spans: List[Tuple[int, int]] = []
    start = 0
    for cut in candidates:
        while cut - start > max_size:
            spans.append((start, max_size))
            start += max_size
        if cut - start < min_size:
            continue
        spans.append((start, cut - start))
        start = cut
    while n - start > max_size:
        spans.append((start, max_size))
        start += max_size
    if n > start:
        spans.append((start, n - start))
    return spans


def chunk_blob(data: bytes, **geometry) -> List[Tuple[str, int, int]]:
    """``(chunk sha-256, offset, length)`` for every content-defined span."""
    return [(hashlib.sha256(data[off:off + ln]).hexdigest(), off, ln)
            for off, ln in chunk_spans(data, **geometry)]


# -------------------------------------------------------------------- recipes
#: recipe ops, msgpack-safe: ``["r", <bytes>]`` literal run, ``["c", <hash>]``
#: reference to a chunk the receiver already holds
RAW_OP = "r"
REF_OP = "c"


def build_recipe(data: bytes, chunks: Sequence[Tuple[str, int, int]],
                 have: Set[str]) -> Tuple[List[list], int]:
    """Turn ``data`` into a recipe against the receiver's ``have`` set.

    Adjacent missing chunks coalesce into one literal run.  Returns
    ``(recipe, wire_cost)`` where ``wire_cost`` is the literal bytes plus
    :data:`REF_WIRE_COST` per reference — what the recipe costs to send,
    compared against the whole frame before choosing the delta path."""
    recipe: List[list] = []
    cost = 0
    raw_start: Optional[int] = None
    raw_end = 0

    def flush() -> None:
        nonlocal raw_start, cost
        if raw_start is not None:
            run = data[raw_start:raw_end]
            recipe.append([RAW_OP, run])
            cost += len(run)
            raw_start = None

    for chunk_hash, off, ln in chunks:
        if chunk_hash in have:
            flush()
            recipe.append([REF_OP, chunk_hash])
            cost += REF_WIRE_COST
        else:
            if raw_start is None:
                raw_start = off
            raw_end = off + ln
    flush()
    return recipe, cost


def apply_recipe(recipe: Iterable[Sequence],
                 resolve: Callable[[str], bytes]) -> bytes:
    """Reassemble a recipe: literals verbatim, references through
    ``resolve`` (which must return the exact chunk bytes — the caller
    re-hashes).  Raises :class:`ObjectNotFound` on a malformed op so a
    corrupt wire frame surfaces as a transfer failure, not a crash."""
    parts: List[bytes] = []
    for op in recipe:
        if op[0] == RAW_OP:
            parts.append(bytes(op[1]))
        elif op[0] == REF_OP:
            parts.append(resolve(op[1]))
        else:
            raise ObjectNotFound(f"delta recipe: unknown op {op[0]!r}")
    return b"".join(parts)


# ---------------------------------------------------------------- chunk index
class ChunkIndex:
    """Bounded chunk hash → ``(blob digest, offset, length)`` map a receiver
    maintains over the large blobs it has stored.

    The index is an *acceleration structure*, never a source of truth: a
    lookup only tells the receiver where a chunk's bytes may be found in its
    own store, and every resolved chunk is re-hashed before use — so a stale
    entry (the blob was GC'd since) degrades to "chunk unavailable" and the
    sender falls back to a whole frame for that blob.  LRU-bounded so a
    long-lived server cannot grow it without limit; eviction likewise only
    costs future delta efficiency, never correctness."""

    def __init__(self, max_entries: int = 1 << 16):
        self.max_entries = max(1, max_entries)
        self._map: "OrderedDict[str, Tuple[str, int, int]]" = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def add_blob(self, digest: str, data: bytes,
                 chunks: Optional[Sequence[Tuple[str, int, int]]] = None
                 ) -> int:
        """Index every chunk of ``data`` (chunked here unless the caller
        already did).  Returns the number of chunks indexed."""
        if chunks is None:
            chunks = chunk_blob(data)
        with self._lock:
            for chunk_hash, off, ln in chunks:
                # move-to-end on re-add: recently seen chunks stay resident
                self._map.pop(chunk_hash, None)
                self._map[chunk_hash] = (digest, off, ln)
            while len(self._map) > self.max_entries:
                self._map.popitem(last=False)
        return len(chunks)

    def lookup(self, chunk_hash: str) -> Optional[Tuple[str, int, int]]:
        with self._lock:
            loc = self._map.get(chunk_hash)
            if loc is not None:
                self._map.move_to_end(chunk_hash)
            return loc

    def has(self, hashes: Iterable[str]) -> Set[str]:
        with self._lock:
            return {h for h in hashes if h in self._map}

    def forget_blob(self, digest: str) -> int:
        """Drop every entry pointing into ``digest`` (called when a sweep
        deletes the blob, so lookups stop chasing freed bytes)."""
        with self._lock:
            stale = [h for h, (d, _o, _l) in self._map.items() if d == digest]
            for h in stale:
                del self._map[h]
        return len(stale)


def assemble(recipe: Iterable[Sequence], index: ChunkIndex,
             read_blob: Callable[[str], bytes],
             blob_cache: Optional[Dict[str, bytes]] = None) -> bytes:
    """Receiver-side reassembly: resolve each referenced chunk through the
    index and the local store, re-hash it (the index is untrusted), and
    concatenate.  Raises :class:`ObjectNotFound` when a referenced chunk is
    no longer resolvable — the sender retries that blob whole-frame."""
    cache = blob_cache if blob_cache is not None else {}

    def resolve(chunk_hash: str) -> bytes:
        loc = index.lookup(chunk_hash)
        if loc is None:
            raise ObjectNotFound(f"chunk {chunk_hash[:12]} not indexed")
        digest, off, ln = loc
        data = cache.get(digest)
        if data is None:
            data = read_blob(digest)  # ObjectNotFound propagates (stale)
            cache[digest] = data
        piece = data[off:off + ln]
        if hashlib.sha256(piece).hexdigest() != chunk_hash:
            raise ObjectNotFound(
                f"chunk {chunk_hash[:12]}: index points at mismatching "
                "bytes")
        return piece

    return apply_recipe(recipe, resolve)
