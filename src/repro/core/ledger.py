"""Immutable runs + replay (paper §4–5): every run gets a ``run_id`` that
uniquely pins the combination of code, config, runtime and input-data commit —
``bauplan run --id=1441804`` becomes ``ledger.replay(run_id, ...)``.

The run manifest covers all four rows of the paper's Table 1:

    input data -> data_commit (catalog commit digest at read time)
    code       -> per-node code hashes + pipeline hash
    runtime    -> python/jax versions + node runtime pins (pip={...})
    hardware   -> mesh fingerprint (device kind, axis names, shape)

Replay = checkout a debug branch at ``data_commit``, re-execute the same code,
and (optionally) verify output digests are bit-identical to the original run.
"""

from __future__ import annotations

import hashlib
import platform
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import msgpack

from .catalog import Catalog
from .errors import CodeDrift, RefNotFound, RunNotFound
from .pipeline import ExecutionReport, Pipeline, RunResult, execute
from .runcache import RunCache
from .store import ObjectStore
from .table import TableIO

_RUNS_HEAD = "runs-head"


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


def runtime_fingerprint() -> Dict[str, str]:
    fp = {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
    }
    try:
        import jax

        fp["jax"] = jax.__version__
    except ImportError:  # pragma: no cover
        pass
    return fp


def mesh_fingerprint(mesh=None) -> Dict[str, Any]:
    """Hardware row of Table 1, TPU edition."""
    if mesh is None:
        return {"kind": "unspecified"}
    return {
        "kind": str(getattr(mesh.devices.flat[0], "device_kind", "cpu")),
        "shape": dict(mesh.shape),
        "axes": list(mesh.axis_names),
        "n_devices": int(mesh.devices.size),
    }


@dataclass
class ReplayReport:
    run_id: str
    replay_run_id: str
    branch: str
    bit_exact: bool
    diffs: Dict[str, tuple] = field(default_factory=dict)


class RunLedger:
    """Append-only chain of run manifests in the object store."""

    def __init__(self, store: ObjectStore, clock=time.time):
        self.store = store
        self.clock = clock

    # ---------------------------------------------------------------- record
    def record(
        self,
        *,
        pipeline: Pipeline,
        data_commit: str,
        result_commit: str,
        branch: str,
        outputs: Dict[str, str],
        config: Optional[Dict[str, Any]] = None,
        seed: Optional[int] = None,
        mesh=None,
        parent_run: Optional[str] = None,
        kind: str = "pipeline",
        report: Optional[ExecutionReport] = None,
    ) -> str:
        executor = {}
        nodes = {}
        if report is not None:
            executor = {
                "kind": report.executor,
                "exec_id": report.exec_id,
                "jobs": report.jobs,
                "cache": report.cache_enabled,
                "cache_hits": report.cache_hits,
                "cache_misses": report.cache_misses,
            }
            nodes = {name: stat.to_obj()
                     for name, stat in sorted(report.node_stats.items())}
        manifest = {
            "kind": kind,
            "code": pipeline.code_manifest(),
            "pipeline_hash": pipeline.code_hash(),
            "node_runtime": {n.name: n.runtime
                             for n in pipeline.nodes.values() if n.runtime},
            "data_commit": data_commit,
            "result_commit": result_commit,
            "branch": branch,
            "outputs": dict(sorted(outputs.items())),
            "config": config or {},
            "seed": seed,
            "runtime": runtime_fingerprint(),
            "hardware": mesh_fingerprint(mesh),
            "parent_run": parent_run,
            "executor": executor,  # per-run cache/parallelism settings
            "nodes": nodes,  # per-node cache hit/miss + wall time
            "ts": self.clock(),
        }
        blob = _pack(manifest)
        run_id = hashlib.sha256(blob).hexdigest()[:16]
        payload_digest = self.store.put(blob)
        # append to the run chain (enumerable history of all runs)
        try:
            prev = self.store.get_ref(_RUNS_HEAD)
        except RefNotFound:
            prev = None
        link = self.store.put(_pack({"run_id": run_id,
                                     "manifest": payload_digest,
                                     "prev": prev}))
        self.store.set_ref(_RUNS_HEAD, link)
        return run_id

    # ------------------------------------------------------------------ read
    def _iter_links(self):
        try:
            cur: Optional[str] = self.store.get_ref(_RUNS_HEAD)
        except RefNotFound:
            return
        while cur is not None:
            link = _unpack(self.store.get(cur))
            yield link
            cur = link["prev"]

    def runs(self) -> List[str]:
        return [link["run_id"] for link in self._iter_links()]

    def links(self):
        """Chain links newest-first: ``{run_id, manifest, prev}`` dicts.
        Exposed for push/pull, which graft missing manifests onto the
        destination's own chain instead of copying link blobs (the chains
        on two hosts interleave differently but reference identical,
        content-addressed manifests)."""
        return self._iter_links()

    def graft(self, run_id: str, manifest_digest: str) -> None:
        """Append an existing (transferred) manifest to this store's chain.
        The manifest blob must already be in the store; the run keeps its
        original id so replay-by-id works across hosts."""
        try:
            prev = self.store.get_ref(_RUNS_HEAD)
        except RefNotFound:
            prev = None
        link = self.store.put(_pack({"run_id": run_id,
                                     "manifest": manifest_digest,
                                     "prev": prev}))
        self.store.set_ref(_RUNS_HEAD, link)

    def get(self, run_id: str) -> Dict[str, Any]:
        for link in self._iter_links():
            if link["run_id"] == run_id or link["run_id"].startswith(run_id):
                return _unpack(self.store.get(link["manifest"]))
        raise RunNotFound(run_id)

    # ---------------------------------------------------------------- replay
    def replay(
        self,
        run_id: str,
        pipeline: Pipeline,
        catalog: Catalog,
        io: TableIO,
        *,
        branch: str,
        author: str = "system",
        allow_code_drift: bool = False,
        verify: bool = True,
        cache: Optional[RunCache] = None,
        use_cache: bool = True,
        jobs: Optional[int] = None,
        executor: str = "thread",
        **exec_opts,
    ) -> ReplayReport:
        """Re-execute a past run into a (new) debug branch — use case #2.

        1) time-travel: the debug branch is created at the run's data commit;
        2) code check: the supplied pipeline must hash-match the manifest
           (the paper pins code via its API; we verify and refuse on drift);
        3) re-run + record, and compare output digests to the original.
        """
        manifest = self.get(run_id)
        recorded = manifest["code"]
        current = pipeline.code_manifest()
        if recorded != current and not allow_code_drift:
            drifted = sorted(k for k in set(recorded) | set(current)
                             if recorded.get(k) != current.get(k))
            raise CodeDrift(f"nodes changed since run {run_id}: {drifted}")
        if branch not in catalog.branches():
            catalog.create_branch(branch, manifest["data_commit"],
                                  author=author)
        report = execute(pipeline, catalog, io, branch=branch, author=author,
                         params=manifest["config"].get("params"),
                         read_ref=manifest["data_commit"],
                         cache=cache, use_cache=use_cache, jobs=jobs,
                         executor=executor, **exec_opts)
        outputs = report.outputs
        replay_id = self.record(
            pipeline=pipeline,
            data_commit=manifest["data_commit"],
            result_commit=catalog.head(branch),
            branch=branch,
            outputs=outputs,
            config=manifest["config"],
            seed=manifest["seed"],
            parent_run=run_id,
            kind="replay",
            report=report,
        )
        if report.exec_id:
            from .exec import bind_ledger_run

            bind_ledger_run(self.store, report.exec_id, replay_id)
        diffs = {}
        if verify:
            for name, digest in manifest["outputs"].items():
                new = outputs.get(name)
                if new != digest:
                    diffs[name] = (digest, new)
        return ReplayReport(run_id=run_id, replay_run_id=replay_id,
                            branch=branch, bit_exact=not diffs, diffs=diffs)


def run_pipeline(
    pipeline: Pipeline,
    catalog: Catalog,
    io: TableIO,
    ledger: RunLedger,
    *,
    branch: str,
    author: str = "system",
    config: Optional[Dict[str, Any]] = None,
    seed: Optional[int] = None,
    mesh=None,
    cache: Optional[RunCache] = None,
    use_cache: bool = True,
    jobs: Optional[int] = None,
    executor: str = "thread",
    **exec_opts,
) -> RunResult:
    """``bauplan run``: execute + record, returning the run id.

    ``executor`` / ``exec_opts`` (lease_ttl, max_attempts, poll,
    wait_timeout) pass straight through to :func:`~.pipeline.execute`; the
    run manifest records which backend ran the DAG, and the ledger run id
    is bound back into the execution's refs-keyspace record so
    ``repro status <run-id>`` resolves either identifier."""
    data_commit = catalog.head(branch)
    report = execute(pipeline, catalog, io, branch=branch, author=author,
                     params=(config or {}).get("params"),
                     cache=cache, use_cache=use_cache, jobs=jobs,
                     executor=executor, **exec_opts)
    result_commit = catalog.head(branch)
    run_id = ledger.record(
        pipeline=pipeline, data_commit=data_commit,
        result_commit=result_commit, branch=branch, outputs=report.outputs,
        config=config, seed=seed, mesh=mesh, report=report,
    )
    if report.exec_id:
        from .exec import bind_ledger_run

        bind_ledger_run(catalog.store, report.exec_id, run_id)
    return RunResult(run_id=run_id, commit=result_commit, branch=branch,
                     outputs=report.outputs, node_stats=report.node_stats)
