"""TensorFile — the "Parquet" of the tensor lake (Fig. 2, layer 2).

An immutable, schema-carrying, columnar container for a batch of rows whose
columns are ndarrays (scalars per row or fixed-shape tensors per row).  It is
the unit of content addressing: tables are manifests of tensor-file digests.

Differences from Parquet are deliberate TPU adaptations (see DESIGN.md §2):
columns are dense ndarrays (directly device-puttable), not Arrow buffers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import msgpack
import numpy as np

from .errors import SchemaError

try:  # bfloat16 & friends come with jax
    import ml_dtypes

    _EXTRA_DTYPES = {
        "bfloat16": np.dtype(ml_dtypes.bfloat16),
        "float8_e4m3fn": np.dtype(ml_dtypes.float8_e4m3fn),
        "float8_e5m2": np.dtype(ml_dtypes.float8_e5m2),
    }
except ImportError:  # pragma: no cover
    _EXTRA_DTYPES = {}

_FORMAT_VERSION = 1


def resolve_dtype(name: str) -> np.dtype:
    if name in _EXTRA_DTYPES:
        return _EXTRA_DTYPES[name]
    return np.dtype(name)


@dataclass(frozen=True)
class ColumnSpec:
    """Schema entry: per-row shape (without the leading row axis) + dtype."""

    name: str
    dtype: str
    row_shape: Tuple[int, ...]

    def to_obj(self) -> list:
        return [self.name, self.dtype, list(self.row_shape)]

    @staticmethod
    def from_obj(obj: list) -> "ColumnSpec":
        return ColumnSpec(obj[0], obj[1], tuple(obj[2]))


@dataclass(frozen=True)
class Schema:
    columns: Tuple[ColumnSpec, ...]

    def to_obj(self) -> list:
        return [c.to_obj() for c in self.columns]

    @staticmethod
    def from_obj(obj: list) -> "Schema":
        return Schema(tuple(ColumnSpec.from_obj(o) for o in obj))

    @staticmethod
    def of(cols: Mapping[str, np.ndarray]) -> "Schema":
        specs = []
        for name in sorted(cols):
            arr = np.asarray(cols[name])
            if arr.ndim == 0:
                raise SchemaError(f"column {name!r} must have a row axis")
            specs.append(ColumnSpec(name, arr.dtype.name, tuple(arr.shape[1:])))
        return Schema(tuple(specs))

    def names(self) -> List[str]:
        return [c.name for c in self.columns]

    def check_compatible(self, other: "Schema") -> None:
        if self != other:
            raise SchemaError(f"schema mismatch:\n  {self}\n  {other}")

    def project(self, names) -> "Schema":
        keep = set(names)
        return Schema(tuple(c for c in self.columns if c.name in keep))


def _column_stats(arr: np.ndarray) -> Dict[str, Any]:
    """Min/max/nan-count — the Iceberg-style manifest stats used for pruning
    and for cheap audit expectations."""
    if arr.size == 0 or arr.dtype.kind not in "fiub":
        return {}
    farr = arr.astype(np.float64) if arr.dtype.kind == "f" else arr
    stats: Dict[str, Any] = {}
    if arr.dtype.kind == "f":
        nan_count = int(np.isnan(farr).sum())
        stats["nan_count"] = nan_count
        if nan_count < farr.size:
            stats["min"] = float(np.nanmin(farr))
            stats["max"] = float(np.nanmax(farr))
    else:
        stats["min"] = int(arr.min()) if arr.dtype.kind in "iu" else int(arr.min())
        stats["max"] = int(arr.max()) if arr.dtype.kind in "iu" else int(arr.max())
    return stats


def encode(cols: Mapping[str, np.ndarray]) -> Tuple[bytes, Dict[str, Any]]:
    """Serialize columns → (bytes, meta).  meta carries nrows/schema/stats and
    becomes the manifest entry next to the content digest."""
    if not cols:
        raise SchemaError("tensorfile needs at least one column")
    arrays = {k: np.ascontiguousarray(np.asarray(v)) for k, v in cols.items()}
    nrows = {v.shape[0] for v in arrays.values()}
    if len(nrows) != 1:
        raise SchemaError(f"ragged columns: row counts {sorted(nrows)}")
    (n,) = nrows
    schema = Schema.of(arrays)
    payload = {
        "v": _FORMAT_VERSION,
        "nrows": n,
        "schema": schema.to_obj(),
        "data": {k: arrays[k].tobytes() for k in sorted(arrays)},
    }
    blob = msgpack.packb(payload, use_bin_type=True)
    meta = {
        "nrows": n,
        "schema": schema.to_obj(),
        "stats": {k: _column_stats(arrays[k]) for k in sorted(arrays)},
        "nbytes": sum(a.nbytes for a in arrays.values()),
    }
    return blob, meta


def decode(blob: bytes, columns: Optional[Sequence[str]] = None
           ) -> Dict[str, np.ndarray]:
    """Deserialize a tensorfile.  With ``columns``, only the named columns
    are materialized — the other columns' bytes are never touched, which
    is what makes projected table scans cheap (``TableIO.read(columns=)``
    pushes its selection down to here)."""
    payload = msgpack.unpackb(blob, raw=False)
    if payload.get("v") != _FORMAT_VERSION:
        raise SchemaError(f"unknown tensorfile version {payload.get('v')!r}")
    schema = Schema.from_obj(payload["schema"])
    n = payload["nrows"]
    specs = schema.columns
    if columns is not None:
        by_name = {spec.name: spec for spec in specs}
        missing = sorted(set(columns) - set(by_name))
        if missing:
            raise SchemaError(f"missing columns {missing}")
        specs = [by_name[name] for name in dict.fromkeys(columns)]
    out: Dict[str, np.ndarray] = {}
    for spec in specs:
        raw = payload["data"][spec.name]
        arr = np.frombuffer(raw, dtype=resolve_dtype(spec.dtype))
        out[spec.name] = arr.reshape((n, *spec.row_shape)).copy()
    return out


def concat(frames: List[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Row-concatenate decoded tensorfiles (the table read path)."""
    if not frames:
        return {}
    names = frames[0].keys()
    for f in frames[1:]:
        if f.keys() != names:
            raise SchemaError("cannot concat frames with different columns")
    return {k: np.concatenate([f[k] for f in frames], axis=0) for k in names}
