r"""Node leases and heartbeats in the refs keyspace.

The distributed executor keeps ALL of its coordination state — which node
is pending, who is executing it, until when, how many times it has been
(re-)leased — as tiny mutable refs, CAS'd with the same primitives that
protect branch heads and the PR-5 GC generation token.  That buys the
executor every property the storage substrate already has: leases replicate
through push/pull backends, survive process death, work over the loopback,
HTTP and S3 transports, and are linearizable per ref.

Keyspace (one run = one namespace under ``exec/``):

    exec/<run_id>/run           -> digest of the run-record blob (msgpack:
                                   state, branch, pipeline hash, summary)
    exec/<run_id>/node/<name>   -> lease text ``state|owner|attempt|deadline|payload``

Lease states and CAS transitions::

    pending --claim--> leased --complete--> done
       ^                 |    \--fail-----> failed
       \----requeue------/  (deadline expired: the worker is presumed dead)

``attempt`` counts *claims*: it is preserved by ``requeue`` and incremented
by ``claim``, so the coordinator's poison-pill check ("fail the run after N
lease attempts on one node") reads it straight off the expired lease.  The
``payload`` slot carries a content digest: the task blob while
pending/leased (resolved input snapshots + injected params for remote
workers), the result blob once done, an error blob once failed.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, Iterator, List, Optional

import msgpack

from ..errors import ObjectNotFound, RefNotFound, ReproError
from ..store import StoreBackend, read_ref_or_none, try_cas_ref

#: ref namespace for executor state (leases, heartbeats, run records)
EXEC_REF_PREFIX = "exec/"

PENDING = "pending"
LEASED = "leased"
DONE = "done"
FAILED = "failed"

_NONE = "-"  # empty owner / payload slot in the encoded lease


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


def _is_digest(s: str) -> bool:
    return len(s) == 64 and all(c in "0123456789abcdef" for c in s)


@dataclass(frozen=True)
class Lease:
    """One node's decoded lease state (the parsed ref value)."""

    node: str
    state: str
    owner: str  # "" while pending
    attempt: int  # number of claims so far (0 while never claimed)
    deadline: float  # heartbeat deadline (0.0 while pending)
    payload: str  # task/result/error blob digest, "" when absent

    def encode(self) -> str:
        return "|".join([self.state, self.owner or _NONE, str(self.attempt),
                         repr(self.deadline), self.payload or _NONE])

    @classmethod
    def decode(cls, node: str, text: str) -> "Lease":
        parts = text.split("|")
        if len(parts) != 5:
            raise ReproError(f"corrupt lease for node {node!r}: {text!r}")
        state, owner, attempt, deadline, payload = parts
        return cls(node=node, state=state,
                   owner="" if owner == _NONE else owner,
                   attempt=int(attempt), deadline=float(deadline),
                   payload="" if payload == _NONE else payload)

    def expired(self, now: float) -> bool:
        """A leased node whose worker stopped heartbeating: presumed dead,
        eligible for re-lease."""
        return self.state == LEASED and now > self.deadline


def lease_ref_digests(ref: str, value: str) -> List[str]:
    """Content digests a single ``exec/`` ref pins (GC mark support):
    the run-record blob for ``.../run`` refs, the payload blob for node
    lease refs.  Tolerant of malformed values — GC must never crash on a
    ref it does not understand."""
    if not ref.startswith(EXEC_REF_PREFIX):
        return []
    if ref.endswith("/run"):
        return [value] if _is_digest(value) else []
    try:
        lease = Lease.decode(ref.rsplit("/", 1)[-1], value)
    except (ReproError, ValueError):
        return []
    return [lease.payload] if _is_digest(lease.payload) else []


class LeaseBoard:
    """The lease table of one run: typed CAS transitions over the refs.

    Every mutating method is a single-ref compare-and-set built on
    :func:`~repro.core.store.try_cas_ref` — a lost race returns False/None
    instead of raising, because with several workers racing for the same
    pending node exactly one claim *should* win."""

    def __init__(self, store: StoreBackend, run_id: str, *,
                 clock=time.time):
        self.store = store
        self.run_id = run_id
        self.clock = clock

    # ------------------------------------------------------------ ref names
    @property
    def run_ref(self) -> str:
        return f"{EXEC_REF_PREFIX}{self.run_id}/run"

    def node_ref(self, node: str) -> str:
        return f"{EXEC_REF_PREFIX}{self.run_id}/node/{node}"

    # ----------------------------------------------------------- run record
    def create_run(self, record: Dict) -> None:
        record = dict(record, run_id=self.run_id)
        self.store.set_ref(self.run_ref, self.store.put(_pack(record)))

    def run_record(self) -> Optional[Dict]:
        digest = read_ref_or_none(self.store, self.run_ref)
        if digest is None:
            return None
        try:
            return _unpack(self.store.get(digest))
        except ObjectNotFound:  # record blob GC'd from under the ref
            return None

    def update_run(self, **fields) -> None:
        record = self.run_record() or {"run_id": self.run_id}
        record.update(fields)
        self.store.set_ref(self.run_ref, self.store.put(_pack(record)))

    # ------------------------------------------------------------- the board
    def read(self, node: str) -> Optional[Lease]:
        text = read_ref_or_none(self.store, self.node_ref(node))
        return None if text is None else Lease.decode(node, text)

    def board(self) -> Dict[str, Lease]:
        """Every node's current lease, one paged listing."""
        prefix = f"{EXEC_REF_PREFIX}{self.run_id}/node/"
        out: Dict[str, Lease] = {}
        token: Optional[str] = None
        while True:
            page, token = self.store.list_refs(prefix, page_token=token,
                                               limit=500)
            for name, value in page:
                node = name[len(prefix):]
                out[node] = Lease.decode(node, value)
            if token is None:
                return out

    # ---------------------------------------------------------- transitions
    def publish(self, node: str, task_digest: str = "") -> Lease:
        """Make a ready node claimable (state pending).  The task blob
        carries everything a remote worker needs beyond the pipeline code:
        resolved input snapshot digests and injected params."""
        lease = Lease(node=node, state=PENDING, owner="", attempt=0,
                      deadline=0.0, payload=task_digest)
        self.store.set_ref(self.node_ref(node), lease.encode())
        return lease

    def claim(self, node: str, owner: str, ttl: float) -> Optional[Lease]:
        """pending -> leased, or None if the node is not claimable / a
        concurrent claimer won the CAS."""
        cur = self.read(node)
        if cur is None or cur.state != PENDING:
            return None
        new = replace(cur, state=LEASED, owner=owner,
                      attempt=cur.attempt + 1,
                      deadline=self.clock() + ttl)
        if try_cas_ref(self.store, self.node_ref(node), cur.encode(),
                       new.encode()):
            return new
        return None

    def lease_direct(self, node: str, owner: str, ttl: float) -> Lease:
        """Publish + claim in one write — the in-process executors, where
        the coordinator IS the worker and nobody races for the node."""
        lease = Lease(node=node, state=LEASED, owner=owner, attempt=1,
                      deadline=self.clock() + ttl, payload="")
        self.store.set_ref(self.node_ref(node), lease.encode())
        return lease

    def heartbeat(self, lease: Lease, ttl: float) -> Optional[Lease]:
        """Extend a held lease's deadline.  None means the lease was lost
        (expired and re-leased to someone else) — the worker must abandon
        the node; its writes are harmless (content-addressed, idempotent)
        but it no longer owns completion."""
        cur = self.read(lease.node)
        if cur is None or cur.state != LEASED or cur.owner != lease.owner \
                or cur.attempt != lease.attempt:
            return None
        new = replace(cur, deadline=self.clock() + ttl)
        if try_cas_ref(self.store, self.node_ref(lease.node), cur.encode(),
                       new.encode()):
            return new
        return None

    def complete(self, lease: Lease, result_digest: str) -> bool:
        """leased -> done, guarded on still owning the lease."""
        return self._finish(lease, DONE, result_digest)

    def fail(self, lease: Lease, error_digest: str) -> bool:
        """leased -> failed (the worker observed a real node error and is
        reporting it — distinct from crashing, which reports nothing and
        surfaces as lease expiry)."""
        return self._finish(lease, FAILED, error_digest)

    def _finish(self, lease: Lease, state: str, payload: str) -> bool:
        cur = self.read(lease.node)
        if cur is None or cur.state != LEASED or cur.owner != lease.owner \
                or cur.attempt != lease.attempt:
            return False
        new = replace(cur, state=state, payload=payload)
        return try_cas_ref(self.store, self.node_ref(lease.node),
                           cur.encode(), new.encode())

    def requeue(self, lease: Lease) -> bool:
        """Expired leased -> pending, preserving the attempt counter (the
        next claim increments it — that is what the poison pill counts).
        The original task payload is restored so the re-lease needs no new
        blob."""
        cur = self.read(lease.node)
        if cur is None or cur.state != LEASED \
                or cur.attempt != lease.attempt:
            return False  # someone else already handled it
        new = replace(cur, state=PENDING, owner="", deadline=0.0)
        return try_cas_ref(self.store, self.node_ref(lease.node),
                           cur.encode(), new.encode())

    def poison(self, lease: Lease, error_digest: str) -> bool:
        """Force a node to failed regardless of owner — the coordinator's
        poison pill after ``max_attempts`` lease claims."""
        cur = self.read(lease.node)
        if cur is None or cur.state in (DONE, FAILED):
            return False
        new = replace(cur, state=FAILED, payload=error_digest)
        return try_cas_ref(self.store, self.node_ref(lease.node),
                           cur.encode(), new.encode())

    # -------------------------------------------------------------- cleanup
    def delete_nodes(self) -> None:
        """Drop the per-node lease refs (run complete; the run record keeps
        the final per-node summary for ``repro status``)."""
        prefix = f"{EXEC_REF_PREFIX}{self.run_id}/node/"
        for ref in list(self.store.iter_refs(prefix)):
            try:
                self.store.delete_ref(ref)
            except RefNotFound:
                pass

    # ------------------------------------------------------------ discovery
    @staticmethod
    def list_runs(store: StoreBackend) -> Iterator[str]:
        """All run ids with executor state in this store, newest unordered
        (run ids are content hashes; callers sort by record timestamp)."""
        seen = set()
        for ref in store.iter_refs(EXEC_REF_PREFIX):
            rest = ref[len(EXEC_REF_PREFIX):]
            run_id = rest.split("/", 1)[0]
            if run_id not in seen:
                seen.add(run_id)
                yield run_id
