"""The DAG coordinator: leases ready nodes to workers, collects results,
commits once.

This is the scheduler that used to live inside ``pipeline.execute`` as a
monolithic thread-pool loop.  Split out, it owns exactly three concerns:

1. **Readiness** — dependency counting over the pipeline's internal edges;
   a node is dispatched the moment its last parent completes.
2. **Leasing** — every dispatched node gets a lease ref under
   ``exec/<run-id>/node/<name>`` (:mod:`.lease`), so ``repro status`` can
   watch any run live and remote workers coordinate through CAS alone.
3. **Outcome handling** — completed nodes unlock children; a failed node
   aborts the run: in-flight siblings are drained (they finish but publish
   no snapshots or cache entries), then :class:`NodeExecutionError`
   propagates carrying the failing node's identity and every completed
   sibling's :class:`NodeStat` — the two things the old scheduler threw
   away.

The execution itself — cache probe, input load, function call, snapshot
write — is :func:`~.worker.run_spec`, shared verbatim by all three worker
backends, which (with content addressing) is why thread, process and
remote runs commit bit-identical digests.
"""

from __future__ import annotations

import hashlib
import inspect
import os
import time
import warnings
from concurrent.futures import FIRST_COMPLETED, wait as futures_wait
from typing import Any, Dict, List, Optional, Set, Tuple

import msgpack

from ..catalog import Catalog
from ..errors import (NodeExecutionError, RefNotFound, ReproError,
                      RunAborted, TableNotFound)
from ..pipeline import ExecutionReport, Pipeline, default_jobs
from ..runcache import CacheDemotionWarning, RunCache, node_key
from ..table import TableIO
from .lease import DONE, FAILED, LEASED, Lease, LeaseBoard
from .worker import (ExecContext, NodeSpec, ProcessWorkerPool, SpecInput,
                     ThreadWorkerPool, read_error, read_result,
                     store_root_of)

EXECUTORS = ("thread", "process", "remote")

#: (node name, code hash) pairs already warned about in this process —
#: the TypeError demotion fires at most one CacheDemotionWarning per node.
_DEMOTION_WARNED: Set[Tuple[str, str]] = set()


def _reset_demotion_warnings() -> None:
    """Test hook: forget which nodes already warned."""
    _DEMOTION_WARNED.clear()


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def new_exec_id(branch: str, pipeline_hash: str) -> str:
    """Short unique id for one execution's lease namespace.  Uniqueness is
    what matters (two concurrent runs of the same pipeline must not share
    lease refs); it is deliberately NOT content-derived."""
    material = ":".join([branch, pipeline_hash, str(time.time_ns()),
                         str(os.getpid()), os.urandom(8).hex()])
    return hashlib.sha256(material.encode()).hexdigest()[:16]


class _Coordinator:
    """One run's scheduling state (shared by the local and remote loops)."""

    def __init__(self, pipeline: Pipeline, catalog: Catalog, io: TableIO, *,
                 branch: str, author: str, params: Dict[str, Any],
                 read_ref: str, run_cache: Optional[RunCache],
                 use_cache: bool, jobs: int, executor: str, exec_id: str,
                 lease_ttl: float, max_attempts: int, poll: float,
                 wait_timeout: Optional[float]):
        self.pipeline = pipeline
        self.catalog = catalog
        self.io = io
        self.branch = branch
        self.author = author
        self.params = params
        self.read_ref = read_ref
        self.run_cache = run_cache
        self.use_cache = use_cache
        self.jobs = jobs
        self.executor = executor
        self.exec_id = exec_id
        self.lease_ttl = lease_ttl
        self.max_attempts = max_attempts
        self.poll = poll
        self.wait_timeout = wait_timeout

        self.store = catalog.store
        self.board = LeaseBoard(self.store, exec_id)
        self.head_tables = catalog.input_digests(read_ref,
                                                 pipeline.source_tables())
        #: branch head when the run started — the base of the run's
        #: output transaction (commit_outputs declares head_tables as its
        #: read set against this base, so a concurrent commit to a table
        #: the DAG never read rebases cleanly instead of conflicting)
        try:
            self.txn_base = catalog.head(branch)
        except RefNotFound:  # branch created later: base = head at commit
            self.txn_base = None
        self.internal = set(pipeline.nodes)
        #: completed nodes' results (the readiness + cache-keying substrate)
        self.results: Dict[str, NodeResult] = {}
        self.waiting = dict(pipeline.indegree)
        self.children = pipeline.children

    # -------------------------------------------------------------- specs
    def input_digest(self, dep: str) -> str:
        """Identity of one input: parent snapshot digest (internal node) or
        source-table snapshot digest on ``read_ref`` (the data-commit half
        of the paper's reproducibility contract)."""
        if dep in self.internal:
            snap = self.results[dep].snapshot
            if snap is None:  # parent ran uncached & unmaterialized
                raise ReproError(
                    f"node {dep!r} has no snapshot for cache keying")
            return snap
        if dep not in self.head_tables:
            raise TableNotFound(
                f"source table {dep!r} not on {self.read_ref!r}")
        return self.head_tables[dep]

    def build_spec(self, name: str) -> NodeSpec:
        """Resolve one ready node into a self-contained :class:`NodeSpec`.

        Called only once every parent has completed, so every input can be
        pinned to a snapshot digest here, on the coordinator — workers
        never re-derive identities, which keeps the cache key computation
        in exactly one place (and byte-identical to the pre-split
        executor's)."""
        node = self.pipeline.nodes[name]
        skip_reason: Optional[str] = None
        node_caching = self.run_cache is not None
        if node_caching and not node.cache_safe:
            # captured state (mutable closure/global) the code hash can't
            # cover — never cache, but still snapshot for descendants' keys
            node_caching, skip_reason = False, "unstable-capture"

        inputs: List[Tuple[str, str]] = []
        if node_caching:
            inputs = [(m.name, self.input_digest(m.name))
                      for m in node.dep_params.values()]
        sig = inspect.signature(node.fn)
        injected = {p: self.params[p] for p in sig.parameters
                    if p in self.params and p not in node.dep_params}
        key: Optional[str] = None
        if node_caching:
            try:
                key = node_key(node.code_hash, inputs, injected, name=name)
            except TypeError as e:  # param with no stable canonical form
                key, inputs = None, []
                skip_reason = "unhashable-param"
                mark = (name, node.code_hash)
                if mark not in _DEMOTION_WARNED:
                    _DEMOTION_WARNED.add(mark)
                    warnings.warn(
                        f"node {name!r} demoted to uncacheable: {e}",
                        CacheDemotionWarning, stacklevel=4)
        if key is None:
            # cache keying didn't walk the inputs — validate sources exist
            for mref in node.dep_params.values():
                if mref.name not in self.internal \
                        and mref.name not in self.head_tables:
                    raise TableNotFound(
                        f"source table {mref.name!r} not on "
                        f"{self.read_ref!r}")

        spec_inputs: List[SpecInput] = []
        for pname, mref in node.dep_params.items():
            if mref.name in self.internal:
                snapshot = self.results[mref.name].snapshot
            else:
                snapshot = self.head_tables[mref.name]
            spec_inputs.append(SpecInput(param=pname, dep=mref.name,
                                         snapshot=snapshot,
                                         columns=mref.columns))
        return NodeSpec(
            name=name, code_hash=node.code_hash,
            materialize=node.materialize,
            # persist whenever caching is on (a cache entry must point at a
            # snapshot; an uncacheable node's snapshot is its descendants'
            # cache input) or when columns cannot flow in memory
            persist=self.run_cache is not None or self.executor != "thread",
            cache_key=key, cache_skip_reason=skip_reason,
            input_digests=inputs, inputs=spec_inputs, injected=injected)

    # ------------------------------------------------------------ lifecycle
    def open_run(self) -> None:
        self.board.create_run({
            "state": "running",
            "branch": self.branch,
            "read_ref": self.read_ref,
            "executor": self.executor,
            "jobs": self.jobs,
            "use_cache": self.use_cache,
            "pipeline_hash": self.pipeline.code_hash(),
            "code": self.pipeline.code_manifest(),
            "total_nodes": len(self.pipeline.order),
            "started": time.time(),
        })

    def ready_roots(self) -> List[str]:
        return [n for n in self.pipeline.order if self.waiting[n] == 0]

    def unlock_children(self, name: str) -> List[str]:
        """Parents-done bookkeeping; returns newly ready nodes."""
        ready = []
        for child in self.children[name]:
            self.waiting[child] -= 1
            if self.waiting[child] == 0:
                ready.append(child)
        return ready

    def stats_so_far(self) -> Dict[str, Any]:
        return {name: r.stat() for name, r in self.results.items()}

    def fail_run(self, node: str, message: str, attempts: int) -> None:
        self.board.update_run(
            state="failed", failed_node=node, error=message,
            finished=time.time(),
            nodes={n: r.stat().to_obj() for n, r in self.results.items()})

    def finish_run(self, commit: Optional[str]) -> None:
        self.board.update_run(
            state="done", commit=commit, finished=time.time(),
            nodes={n: r.stat().to_obj() for n, r in self.results.items()})
        # the per-node lease refs were scaffolding; the run record keeps
        # the final summary for ``repro status``
        self.board.delete_nodes()

    def commit_outputs(self) -> ExecutionReport:
        """The single multi-table transaction (paper §3) — identical logic
        and metadata to the pre-split executor, so commit digests are
        unchanged across the refactor."""
        outputs = {name: r.snapshot for name, r in self.results.items()
                   if self.pipeline.nodes[name].materialize and r.snapshot}
        node_stats = self.stats_so_far()
        commit_digest: Optional[str] = None
        if outputs:
            # Warm replay on an unchanged branch is a no-op: skip the
            # commit when every output table already sits at the same
            # snapshot on the head.
            current = self.catalog.tables(self.branch)
            if any(current.get(n) != s for n, s in outputs.items()):
                n_hits = sum(1 for s in node_stats.values() if s.cache_hit)
                commit_digest = self.catalog.commit(
                    self.branch, outputs,
                    f"pipeline run: {', '.join(self.pipeline.order)}",
                    author=self.author,
                    meta={"pipeline_code": self.pipeline.code_hash(),
                          "cache_hits": n_hits,
                          "cache_misses": len(node_stats) - n_hits},
                    # declared transaction: outputs ∪ source tables, from
                    # the head at run start — concurrent commits to other
                    # tables on the branch rebase instead of conflicting
                    read_tables=sorted(set(self.head_tables)
                                       - set(outputs)),
                    base=self.txn_base,
                )
        self.finish_run(commit_digest)
        return ExecutionReport(outputs=outputs, commit=commit_digest,
                               node_stats=node_stats, jobs=self.jobs,
                               cache_enabled=self.use_cache,
                               executor=self.executor,
                               exec_id=self.exec_id)

    # ----------------------------------------------------------- local loop
    def run_local(self) -> ExecutionReport:
        """thread/process executors: the coordinator IS the worker host.

        Leases are taken with single-write ``lease_direct`` (nobody races
        for the node), but they are real leases — ``repro status`` on a
        local run shows the same board a remote run would."""
        ctx = ExecContext(self.store, cache=self.run_cache)
        if self.executor == "process":
            pool = ProcessWorkerPool(store_root_of(self.store), self.jobs,
                                     ctx=ctx)
        else:
            pool = ThreadWorkerPool(ctx, self.jobs)
        owner = f"local:{os.getpid()}"
        futures: Dict[Any, Tuple[str, Lease]] = {}

        def dispatch(name: str) -> None:
            spec = self.build_spec(name)
            lease = self.board.lease_direct(name, owner, self.lease_ttl)
            fut = pool.submit(spec, self.pipeline.nodes[name].fn)
            futures[fut] = (name, lease)

        def drain() -> None:
            """A failure was observed: no in-flight sibling may publish
            state after it.  Threads cannot be cancelled, so the abort
            flag makes ``run_spec`` discard their outputs; here we wait
            them out so nothing outlives the raised error."""
            ctx.abort.set()
            for fut in list(futures):
                try:
                    fut.result()
                except Exception:  # noqa: BLE001 - first failure wins
                    pass
            futures.clear()

        try:
            for name in self.ready_roots():
                dispatch(name)
            while futures:
                done, _ = futures_wait(futures, return_when=FIRST_COMPLETED)
                for fut in done:
                    name, lease = futures.pop(fut)
                    try:
                        result = fut.result()
                    except RunAborted:
                        continue  # drained sibling (abort already set)
                    except Exception as e:  # noqa: BLE001 - node failure
                        self.board.fail(lease, self.store.put(_pack(
                            {"node": name, "error": repr(e),
                             "owner": owner})))
                        drain()
                        self.fail_run(name, repr(e), lease.attempt)
                        if isinstance(e, ReproError):
                            # contract errors (SchemaError, missing
                            # snapshots) already name the node — keep
                            # their precise type for callers
                            raise
                        raise NodeExecutionError(
                            name, e, node_stats=self.stats_so_far(),
                            attempts=lease.attempt) from e
                    result.attempt = lease.attempt
                    result.owner = owner
                    self.board.complete(
                        lease, self.store.put(_pack(result.to_obj())))
                    self.results[name] = result
                    for child in self.unlock_children(name):
                        dispatch(child)
        except BaseException as e:
            if futures:  # interrupted mid-run (not via the failure path)
                drain()
            if not isinstance(e, NodeExecutionError):
                self.board.update_run(state="failed", error=repr(e),
                                      finished=time.time())
            raise
        finally:
            pool.shutdown()
        return self.commit_outputs()

    # ---------------------------------------------------------- remote loop
    def run_remote(self) -> ExecutionReport:
        """remote executor: publish node leases, let ``repro worker``
        processes claim them, poll the board for outcomes.

        Crash detection is purely temporal: a worker that dies stops
        heartbeating, its lease deadline passes, and the coordinator
        requeues the node (``attempt`` preserved; the next claim increments
        it).  After ``max_attempts`` claims of one node the coordinator
        poisons it — repeated worker death on the same node means the node
        is killing its workers."""
        inflight: Set[str] = set()

        def publish(name: str) -> None:
            spec = self.build_spec(name)
            task = self.store.put(_pack(spec.to_obj()))
            self.board.publish(name, task)
            inflight.add(name)

        def fail_remote(name: str, message: str, attempts: int):
            self.fail_run(name, message, attempts)
            return NodeExecutionError(name, message,
                                      node_stats=self.stats_so_far(),
                                      attempts=attempts)

        for name in self.ready_roots():
            publish(name)
        last_progress = time.monotonic()
        while inflight:
            progressed = False
            board = self.board.board()
            for name in sorted(inflight):
                lease = board.get(name)
                if lease is None:
                    continue
                if lease.state == DONE:
                    result = read_result(self.store, lease)
                    if result is None:
                        raise fail_remote(
                            name, "worker completed the node but its "
                            "result blob is unreadable", lease.attempt)
                    inflight.discard(name)
                    self.results[name] = result
                    for child in self.unlock_children(name):
                        publish(child)
                    progressed = True
                elif lease.state == FAILED:
                    raise fail_remote(name, read_error(self.store, lease),
                                      lease.attempt)
                elif lease.state == LEASED and lease.expired(time.time()):
                    if lease.attempt >= self.max_attempts:
                        message = (
                            f"lease expired {lease.attempt} time(s) — "
                            f"worker {lease.owner!r} presumed dead; "
                            "poison pill after "
                            f"{self.max_attempts} attempts")
                        self.board.poison(lease, self.store.put(_pack(
                            {"node": name, "error": message,
                             "owner": lease.owner})))
                        raise fail_remote(name, message, lease.attempt)
                    if self.board.requeue(lease):
                        progressed = True  # the run is still moving
            if progressed:
                last_progress = time.monotonic()
            elif self.wait_timeout is not None \
                    and time.monotonic() - last_progress > self.wait_timeout:
                stuck = ", ".join(sorted(inflight))
                self.board.update_run(state="failed", finished=time.time(),
                                      error=f"stalled on: {stuck}")
                raise ReproError(
                    f"remote execution stalled for {self.wait_timeout}s "
                    f"waiting on nodes: {stuck} (no workers polling? "
                    "start one with `repro worker`)")
            if inflight:
                time.sleep(self.poll)
        return self.commit_outputs()


def run_dag(pipeline: Pipeline, catalog: Catalog, io: TableIO, *,
            branch: str, author: str = "system",
            params: Optional[Dict[str, Any]] = None,
            read_ref: Optional[str] = None,
            cache: Optional[RunCache] = None, use_cache: bool = True,
            jobs: Optional[int] = None, executor: str = "thread",
            exec_id: Optional[str] = None, lease_ttl: float = 30.0,
            max_attempts: int = 3, poll: float = 0.05,
            wait_timeout: Optional[float] = None) -> ExecutionReport:
    """Entry point behind :func:`repro.core.pipeline.execute` — see its
    docstring for the executor contract."""
    if executor not in EXECUTORS:
        raise ReproError(
            f"unknown executor {executor!r} (expected one of {EXECUTORS})")
    params = params or {}
    read_ref = read_ref or branch
    run_cache = (cache or RunCache(catalog.store)) if use_cache else None
    n_jobs = max(1, jobs) if jobs else default_jobs()
    exec_id = exec_id or new_exec_id(branch, pipeline.code_hash())

    coord = _Coordinator(
        pipeline, catalog, io, branch=branch, author=author, params=params,
        read_ref=read_ref, run_cache=run_cache, use_cache=use_cache,
        jobs=n_jobs, executor=executor, exec_id=exec_id,
        lease_ttl=lease_ttl, max_attempts=max_attempts, poll=poll,
        wait_timeout=wait_timeout)
    coord.open_run()
    if executor == "remote":
        return coord.run_remote()
    return coord.run_local()


# ------------------------------------------------------------------ status
def bind_ledger_run(store, exec_id: str, ledger_run_id: str) -> None:
    """Cross-link an execution's refs-keyspace record to its ledger run id
    so ``repro status`` resolves either name."""
    LeaseBoard(store, exec_id).update_run(ledger_run_id=ledger_run_id)


def run_status(store, run_id: str) -> Dict[str, Any]:
    """Live (or final) view of one execution: the run record merged with
    the current lease board.

    ``run_id`` may be a unique prefix of the exec id, or a ledger run id
    bound via :func:`bind_ledger_run`.  While the run is in flight each
    node shows its lease state/owner/attempt and heartbeat headroom; after
    completion the node view comes from the record's final summary."""
    matches = [r for r in LeaseBoard.list_runs(store)
               if r.startswith(run_id)]
    if not matches:  # fall back to ledger run ids bound into records
        for rid in LeaseBoard.list_runs(store):
            record = LeaseBoard(store, rid).run_record() or {}
            if record.get("ledger_run_id") == run_id:
                matches.append(rid)
    if not matches:
        raise ReproError(f"no execution state for run {run_id!r}")
    if len(matches) > 1:
        raise ReproError(
            f"ambiguous run id {run_id!r}: matches {sorted(matches)}")
    board = LeaseBoard(store, matches[0])
    record = board.run_record() or {}
    now = time.time()
    nodes: Dict[str, Dict[str, Any]] = {}
    for name, stat in (record.get("nodes") or {}).items():
        nodes[name] = dict(stat, state="done")
    for name, lease in board.board().items():
        entry: Dict[str, Any] = {"state": lease.state}
        if lease.state == LEASED:
            entry.update(owner=lease.owner, attempt=lease.attempt,
                         heartbeat_in=round(lease.deadline - now, 3),
                         expired=lease.expired(now))
        elif lease.attempt:
            entry.update(owner=lease.owner, attempt=lease.attempt)
        nodes[name] = {**nodes.get(name, {}), **entry}
    record["exec_id"] = matches[0]
    record["nodes"] = nodes
    return record
