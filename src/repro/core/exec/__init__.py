"""Distributed DAG execution: coordinator, leases, worker backends.

The public surface:

* :func:`run_dag` — the scheduler behind ``pipeline.execute``
* :class:`WorkerService` — the pull-based remote worker (``repro worker``)
* :class:`LeaseBoard` / :class:`Lease` — the refs-keyspace lease table
* :func:`run_status` — live per-node view of a run (``repro status``)
"""

from .coordinator import (bind_ledger_run, new_exec_id, run_dag,
                          run_status)
from .lease import (DONE, EXEC_REF_PREFIX, FAILED, LEASED, PENDING, Lease,
                    LeaseBoard, lease_ref_digests)
from .worker import (ExecContext, NodeResult, NodeSpec, ProcessWorkerPool,
                     SpecInput, ThreadWorkerPool, WorkerService,
                     read_error, read_result, run_spec)

__all__ = [
    "DONE", "EXEC_REF_PREFIX", "FAILED", "LEASED", "PENDING",
    "ExecContext", "Lease", "LeaseBoard", "NodeResult", "NodeSpec",
    "ProcessWorkerPool", "SpecInput", "ThreadWorkerPool", "WorkerService",
    "bind_ledger_run", "lease_ref_digests", "new_exec_id", "read_error",
    "read_result", "run_dag", "run_spec", "run_status",
]
