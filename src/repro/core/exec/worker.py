"""Worker backends for the DAG executor.

One execution core, three transports:

* :class:`ThreadWorkerPool` — the original in-process thread pool.  Node
  outputs stay in memory and flow to children without re-reading snapshots.
* :class:`ProcessWorkerPool` — a local process pool for GIL-bound nodes
  (the long-standing run-cache follow-up).  Each subprocess opens its own
  handle on the same filesystem store, so the run cache doubles as the
  cross-process memo table: a node computed in any worker is a warm hit in
  every other.  Nodes whose function cannot be pickled (closures defined
  inside another function) transparently fall back to a thread.
* :class:`WorkerService` — the remote worker: a poll loop any host can run
  (``repro worker``) against a shared store backend.  It discovers
  in-progress runs in the refs keyspace, claims pending node leases via
  CAS, heartbeats while executing, and publishes result snapshots back
  through the store — the existing push/pull-grade machinery — so the
  shared run cache becomes a cluster-wide memo table.

The execution core itself is :func:`run_spec` over a :class:`NodeSpec`:
a picklable, msgpack-able description of ONE node invocation with every
input already resolved to a snapshot digest.  Code never travels — a
remote worker supplies its own :class:`~repro.core.pipeline.Pipeline` and
is matched to a run by pipeline code hash (the paper's code-version pin),
refusing silently-drifted code the same way replay does.
"""

from __future__ import annotations

import threading
import time
import traceback
from concurrent.futures import Future, ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import msgpack
import numpy as np

from .. import frame as F
from ..errors import ObjectNotFound, ReproError, RunAborted, SchemaError
from ..pipeline import NodeStat, Pipeline
from ..runcache import RunCache
from ..store import ObjectStore, StoreBackend
from ..table import TableIO
from .lease import DONE, LEASED, PENDING, Lease, LeaseBoard


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


# --------------------------------------------------------------- value codec
# Injected params cross the wire inside task blobs; msgpack has no native
# ndarray/tuple, so both get explicit markers and round-trip exactly.

def _enc_value(v: Any):
    if isinstance(v, np.ndarray):
        a = np.ascontiguousarray(v)
        return {"__nd__": [a.dtype.str, list(a.shape), a.tobytes()]}
    if isinstance(v, np.generic):
        a = np.asarray(v)
        return {"__npg__": [a.dtype.str, a.tobytes()]}
    if isinstance(v, tuple):
        return {"__tup__": [_enc_value(x) for x in v]}
    if isinstance(v, list):
        return [_enc_value(x) for x in v]
    if isinstance(v, dict):
        return {k: _enc_value(x) for k, x in v.items()}
    return v


def _dec_value(v: Any):
    if isinstance(v, dict):
        if "__nd__" in v:
            dtype, shape, raw = v["__nd__"]
            return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)
        if "__npg__" in v:
            dtype, raw = v["__npg__"]
            return np.frombuffer(raw, dtype=np.dtype(dtype))[0]
        if "__tup__" in v:
            return tuple(_dec_value(x) for x in v["__tup__"])
        return {k: _dec_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_dec_value(x) for x in v]
    return v


# ------------------------------------------------------------------- specs
@dataclass
class SpecInput:
    """One resolved node input: parameter name, parent/table name, the
    snapshot digest to read (None only in thread mode, where an
    unmaterialized uncached parent's columns flow in memory), and the
    optional column projection from the ``Model`` ref."""

    param: str
    dep: str
    snapshot: Optional[str]
    columns: Optional[List[str]] = None


@dataclass
class NodeSpec:
    """Everything one node invocation needs except the function itself."""

    name: str
    code_hash: str
    materialize: bool
    #: write the output snapshot even when not materializing (forced for
    #: caching — descendants key off it — and for process/remote workers,
    #: where columns cannot flow in memory)
    persist: bool
    cache_key: Optional[str] = None
    #: why this run will not cache the node (None = it will)
    cache_skip_reason: Optional[str] = None
    #: (dep name, snapshot digest) pairs recorded in the cache entry
    input_digests: List[Tuple[str, str]] = field(default_factory=list)
    inputs: List[SpecInput] = field(default_factory=list)
    injected: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self) -> Dict[str, Any]:
        return {
            "name": self.name, "code_hash": self.code_hash,
            "materialize": self.materialize, "persist": self.persist,
            "cache_key": self.cache_key,
            "cache_skip_reason": self.cache_skip_reason,
            "input_digests": [list(p) for p in self.input_digests],
            "inputs": [[i.param, i.dep, i.snapshot, i.columns]
                       for i in self.inputs],
            "injected": {k: _enc_value(v)
                         for k, v in self.injected.items()},
        }

    @classmethod
    def from_obj(cls, o: Mapping[str, Any]) -> "NodeSpec":
        return cls(
            name=o["name"], code_hash=o["code_hash"],
            materialize=o["materialize"], persist=o["persist"],
            cache_key=o.get("cache_key"),
            cache_skip_reason=o.get("cache_skip_reason"),
            input_digests=[tuple(p) for p in o.get("input_digests", [])],
            inputs=[SpecInput(param=i[0], dep=i[1], snapshot=i[2],
                              columns=list(i[3]) if i[3] else None)
                    for i in o.get("inputs", [])],
            injected={k: _dec_value(v)
                      for k, v in o.get("injected", {}).items()},
        )


@dataclass
class NodeResult:
    """What a worker reports back for one executed node."""

    name: str
    snapshot: Optional[str]
    cache_hit: bool
    wall_s: float
    cache_key: Optional[str] = None
    cache_skip_reason: Optional[str] = None
    attempt: int = 1
    owner: str = ""

    def stat(self) -> NodeStat:
        return NodeStat(self.name, self.cache_hit, self.wall_s,
                        self.snapshot, self.cache_key,
                        cache_skip_reason=self.cache_skip_reason,
                        attempts=self.attempt)

    def to_obj(self) -> Dict[str, Any]:
        return {"name": self.name, "snapshot": self.snapshot,
                "cache_hit": self.cache_hit, "wall_s": self.wall_s,
                "cache_key": self.cache_key,
                "cache_skip_reason": self.cache_skip_reason,
                "attempt": self.attempt, "owner": self.owner}

    @classmethod
    def from_obj(cls, o: Mapping[str, Any]) -> "NodeResult":
        return cls(**{k: o.get(k) for k in (
            "name", "snapshot", "cache_hit", "wall_s", "cache_key",
            "cache_skip_reason", "attempt", "owner")})


# ----------------------------------------------------------------- context
class ExecContext:
    """Per-worker execution state: store handles, the in-memory column
    memo, and the abort flag a sibling failure sets.

    ``abort`` is the drain contract: once set, an in-flight node finishes
    its function (threads cannot be killed) but writes NO snapshot and NO
    cache entry — a failed run must not keep publishing state after the
    failure was observed."""

    def __init__(self, store: StoreBackend, *,
                 cache: Optional[RunCache] = None):
        self.store = store
        self.io = TableIO(store)
        self.cache = cache
        self.results: Dict[str, Dict[str, np.ndarray]] = {}
        self._columns: Dict[str, Dict[str, np.ndarray]] = {}
        self._lock = threading.Lock()
        self.abort = threading.Event()

    def columns_of(self, dep: str, snapshot: Optional[str]
                   ) -> Dict[str, np.ndarray]:
        """A dependency's columns: in-memory result if this context ran
        the parent, else a memoized snapshot read."""
        with self._lock:
            cols = self.results.get(dep)
            if cols is None:
                cols = self._columns.get(dep)
        if cols is not None:
            return cols
        if snapshot is None:
            raise ReproError(
                f"node {dep!r} has no snapshot and no in-memory result")
        cols = self.io.read(snapshot)
        with self._lock:
            return self._columns.setdefault(dep, cols)


def run_spec(ctx: ExecContext, spec: NodeSpec,
             fn: Callable[..., Mapping[str, np.ndarray]]) -> NodeResult:
    """Execute one node invocation: cache probe, input load, function call,
    snapshot + cache-entry write.  The single code path every worker kind
    shares — bit-identical outputs across thread/process/remote executors
    follow from content addressing plus this function being the only way a
    node runs."""
    t0 = time.perf_counter()
    if spec.cache_key is not None and ctx.cache is not None:
        entry = ctx.cache.get(spec.cache_key)
        if entry is not None:
            return NodeResult(
                name=spec.name, snapshot=entry["snapshot"], cache_hit=True,
                wall_s=time.perf_counter() - t0, cache_key=spec.cache_key,
                cache_skip_reason=spec.cache_skip_reason)
    kwargs: Dict[str, Any] = {}
    for inp in spec.inputs:
        data = ctx.columns_of(inp.dep, inp.snapshot)
        if inp.columns:
            data = F.select(data, inp.columns)
        kwargs[inp.param] = data
    kwargs.update(spec.injected)
    if ctx.abort.is_set():
        raise RunAborted(spec.name)
    result = fn(**kwargs)
    if not isinstance(result, Mapping) or not result:
        raise SchemaError(
            f"node {spec.name!r} must return a non-empty column mapping")
    result = {k: np.asarray(v) for k, v in result.items()}
    if ctx.abort.is_set():
        # a sibling failed while we were executing: publish nothing
        raise RunAborted(spec.name)
    snapshot: Optional[str] = None
    if spec.materialize or spec.persist:
        snapshot = ctx.io.write_snapshot(result)
    if spec.cache_key is not None and ctx.cache is not None:
        ctx.cache.put(spec.cache_key, node=spec.name, snapshot=snapshot,
                      code_hash=spec.code_hash, inputs=spec.input_digests)
    with ctx._lock:
        ctx.results[spec.name] = result
    return NodeResult(name=spec.name, snapshot=snapshot, cache_hit=False,
                      wall_s=time.perf_counter() - t0,
                      cache_key=spec.cache_key,
                      cache_skip_reason=spec.cache_skip_reason)


# ------------------------------------------------------------- thread pool
class ThreadWorkerPool:
    """The in-process executor: N threads over one shared context."""

    kind = "thread"

    def __init__(self, ctx: ExecContext, jobs: int):
        self.ctx = ctx
        self._pool = ThreadPoolExecutor(max_workers=jobs)

    def submit(self, spec: NodeSpec, fn: Callable) -> Future:
        return self._pool.submit(run_spec, self.ctx, spec, fn)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)


# ------------------------------------------------------------ process pool
_PROC_CTX: Optional[ExecContext] = None


def _proc_init(store_root: str) -> None:
    """Subprocess initializer: open an independent handle on the shared
    filesystem store.  The RunCache on top of it is the cross-process memo
    table — entries written by any worker are visible to all."""
    global _PROC_CTX
    store = ObjectStore(store_root)
    _PROC_CTX = ExecContext(store, cache=RunCache(store))


def _proc_run(spec: NodeSpec, fn: Callable) -> NodeResult:
    return run_spec(_PROC_CTX, spec, fn)


def _picklable(*objs) -> bool:
    import pickle

    try:
        for o in objs:
            pickle.dumps(o)
        return True
    except Exception:  # noqa: BLE001 - any pickle failure means fallback
        return False


class ProcessWorkerPool:
    """Local process pool for GIL-bound nodes.

    Outputs always persist as snapshots (columns cannot cross the process
    boundary), so children in other workers read content-addressed bytes —
    which is exactly why the commit digests stay bit-identical to the
    thread executor.  Unpicklable node functions (closures built inside
    tests or notebooks) degrade to an in-process thread instead of
    failing the run."""

    kind = "process"

    def __init__(self, store_root, jobs: int, *, ctx: ExecContext):
        self.ctx = ctx  # fallback context for unpicklable nodes
        self._pool = ProcessPoolExecutor(
            max_workers=jobs, initializer=_proc_init,
            initargs=(str(store_root),))
        self._fallback: Optional[ThreadPoolExecutor] = None
        self._jobs = jobs

    def submit(self, spec: NodeSpec, fn: Callable) -> Future:
        if not _picklable(fn, spec.injected):
            if self._fallback is None:
                self._fallback = ThreadPoolExecutor(max_workers=self._jobs)
            return self._fallback.submit(run_spec, self.ctx, spec, fn)
        return self._pool.submit(_proc_run, spec, fn)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
        if self._fallback is not None:
            self._fallback.shutdown(wait=True)


def store_root_of(store: StoreBackend):
    """Filesystem root shared with subprocess workers.  A TieredStore
    contributes its local tier (subprocesses see everything the run
    writes; remote-only blobs would need a pull first — documented in
    docs/executor.md)."""
    root = getattr(store, "root", None)
    if root is None:
        root = getattr(getattr(store, "local", None), "root", None)
    if root is None:
        raise ReproError(
            "the process executor needs a filesystem-backed store "
            f"(got {type(store).__name__}); use executor='remote' with "
            "worker processes against a shared store instead")
    return root


# ----------------------------------------------------------- remote worker
class WorkerService:
    """A pull-based worker any host can run against a shared store.

    The loop: discover in-progress runs under ``exec/``, match one to a
    locally registered pipeline by code hash (code is pinned, never
    shipped), claim a pending node lease via CAS, heartbeat while the node
    executes, publish the result blob + snapshot, CAS the lease to done.
    A worker that dies mid-node simply stops heartbeating: the coordinator
    re-leases the node after the deadline and another worker picks it up —
    usually hitting the run cache for whatever the dead worker already
    finished.

    ``trace`` is an optional callable fired at named sync points
    (``worker:claim``, ``worker:execute``, ``worker:complete:before``) —
    the hook tests/fault_schedule.py plugs into to script worker crashes
    deterministically."""

    def __init__(self, store: StoreBackend, pipelines, *,
                 name: str = "worker", ttl: float = 10.0,
                 poll: float = 0.05, clock=time.time,
                 use_cache: bool = True, trace=None):
        self.store = store
        self.pipelines: Dict[str, Pipeline] = {
            p.code_hash(): p for p in pipelines}
        self.name = name
        self.ttl = ttl
        self.poll = poll
        self.clock = clock
        cache = RunCache(store) if use_cache else None
        self.ctx = ExecContext(store, cache=cache)
        self.trace = trace or (lambda point: None)
        self.nodes_done = 0

    # ------------------------------------------------------------- the loop
    def run_once(self) -> bool:
        """Claim and execute at most one node.  True iff work was done."""
        for run_id in LeaseBoard.list_runs(self.store):
            board = LeaseBoard(self.store, run_id, clock=self.clock)
            record = board.run_record()
            if not record or record.get("state") != "running":
                continue
            pipeline = self.pipelines.get(record.get("pipeline_hash"))
            if pipeline is None:
                continue  # code drift or unknown pipeline: never guess
            for node, lease in sorted(board.board().items()):
                if lease.state != PENDING:
                    continue
                claimed = board.claim(node, self.name, self.ttl)
                if claimed is None:
                    continue  # lost the race
                self._execute(board, claimed, pipeline)
                return True
        return False

    def serve_forever(self, stop: Optional[threading.Event] = None,
                      max_idle: Optional[float] = None) -> int:
        """Poll until ``stop`` is set (or ``max_idle`` seconds pass with
        no claimable work).  Returns the number of nodes executed."""
        idle_since = self.clock()
        while stop is None or not stop.is_set():
            if self.run_once():
                idle_since = self.clock()
                continue
            if max_idle is not None and self.clock() - idle_since > max_idle:
                break
            time.sleep(self.poll)
        return self.nodes_done

    # ------------------------------------------------------------ execution
    def _execute(self, board: LeaseBoard, lease: Lease,
                 pipeline: Pipeline) -> None:
        self.trace("worker:claim")
        spec = NodeSpec.from_obj(_unpack(self.store.get(lease.payload)))
        fn = pipeline.nodes[spec.name].fn
        hb_stop = threading.Event()
        hb_lease = [lease]

        def heartbeat():
            while not hb_stop.wait(self.ttl / 3.0):
                renewed = board.heartbeat(hb_lease[0], self.ttl)
                if renewed is None:
                    self.ctx.abort.set()  # lease lost: stop publishing
                    return
                hb_lease[0] = renewed

        hb = threading.Thread(target=heartbeat, daemon=True)
        hb.start()
        try:
            try:
                result = run_spec(self.ctx, spec, fn)
            except RunAborted:
                return  # lease lost mid-execution; the new owner reports
            except Exception as e:  # noqa: BLE001 - report, don't crash
                err = _pack({"node": spec.name, "error": repr(e),
                             "traceback": traceback.format_exc(),
                             "owner": self.name})
                board.fail(hb_lease[0], self.store.put(err))
                return
            result.attempt = lease.attempt
            result.owner = self.name
            self.trace("worker:execute")
            self.trace("worker:complete:before")
            if board.complete(hb_lease[0], self.store.put(
                    _pack(result.to_obj()))):
                self.nodes_done += 1
        finally:
            hb_stop.set()
            self.ctx.abort.clear()


def read_result(store: StoreBackend, lease: Lease) -> Optional[NodeResult]:
    """The NodeResult a done lease points at (None if the blob is gone)."""
    if lease.state != DONE or not lease.payload:
        return None
    try:
        return NodeResult.from_obj(_unpack(store.get(lease.payload)))
    except ObjectNotFound:
        return None


def read_error(store: StoreBackend, lease: Lease) -> str:
    """Human-readable failure reason from a failed lease's error blob."""
    if lease.payload:
        try:
            err = _unpack(store.get(lease.payload))
            return err.get("error", "unknown error")
        except ObjectNotFound:
            pass
    return "worker reported failure (error blob unavailable)"
