"""Data contracts: WAP expectations promoted into the catalog itself.

A *contract* is a set of named rules attached to a table.  Contracts live
in the commit object as a reserved table entry (``__contracts__`` →
msgpack blob of rule specs), so they are versioned, branched and merged
exactly like data: a debug branch inherits its parent's contracts, and a
contract added on a feature branch rides the merge into ``main``.

Unlike ``wap.Expectation`` — an opt-in audit a *cooperating* caller runs
before publishing — a contract is enforced by ``Catalog.commit`` /
``Catalog.merge`` at the ref update itself.  An untrusted or agentic
writer cannot land violating data by skipping the write-audit-publish
ceremony: the commit that would move the branch head is rejected with
:class:`~.errors.ContractViolation` before any ref moves.

Rules are *specs*, not closures: ``Rule(kind, args)`` where ``kind`` names
a builder in the rule registry.  That keeps contracts serializable (they
live in the store) and evaluable by any host — the same reason the run
cache keys on code hashes instead of pickled functions.  Built-in kinds
mirror the ``wap`` helpers (``not_empty``, ``no_nans``, ``column_range``)
plus ``columns_required``; :func:`register_rule` extends the registry for
project-specific checks (unknown kinds fail closed: the commit is
rejected, never silently waved through).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import msgpack
import numpy as np

from .errors import ReproError

#: reserved entry in ``Commit.tables`` holding the contracts blob digest.
#: Regular commits may not write it directly — ``Catalog.add_contract`` /
#: ``drop_contract`` are the only mutators — but it merges like any other
#: table (both sides changing contracts since the base is a conflict).
CONTRACTS_TABLE = "__contracts__"

Frame = Mapping[str, np.ndarray]


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass(frozen=True)
class Rule:
    """One serializable check: a registry kind plus its parameters."""

    kind: str
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def name(self) -> str:
        if not self.args:
            return self.kind
        parts = ",".join(f"{k}={self.args[k]}" for k in sorted(self.args))
        return f"{self.kind}({parts})"

    def to_obj(self):
        return {"kind": self.kind, "args": dict(self.args)}

    @staticmethod
    def from_obj(o) -> "Rule":
        return Rule(o["kind"], dict(o.get("args", {})))


@dataclass(frozen=True)
class Contract:
    """All rules attached to one table (evaluated on every new snapshot)."""

    table: str
    rules: Tuple[Rule, ...]
    author: str = "system"

    def to_obj(self):
        return {"table": self.table, "author": self.author,
                "rules": [r.to_obj() for r in self.rules]}

    @staticmethod
    def from_obj(o) -> "Contract":
        return Contract(o["table"],
                        tuple(Rule.from_obj(r) for r in o["rules"]),
                        o.get("author", "system"))


def rule(kind: str, **args) -> Rule:
    """``rule("column_range", column="p", lo=0.0, hi=1.0)`` — validated
    against the registry eagerly so a typo'd kind fails at authoring time,
    not at the first commit it should have gated."""
    if kind not in _RULES:
        raise ReproError(
            f"unknown contract rule kind {kind!r} "
            f"(registered: {sorted(_RULES)})")
    return Rule(kind, args)


# --------------------------------------------------------------- registry
#: kind -> builder(args) -> (frame -> bool)
_RULES: Dict[str, Callable[[Dict[str, Any]], Callable[[Frame], bool]]] = {}


def register_rule(kind: str,
                  builder: Callable[[Dict[str, Any]],
                                    Callable[[Frame], bool]]) -> None:
    """Extend the registry (project-specific checks).  The kind string is
    what travels in the store; every host that commits to a contracted
    table must have it registered, or its commits fail closed."""
    _RULES[kind] = builder


def _not_empty(args):
    def fn(f: Frame) -> bool:
        return bool(f) and all(np.asarray(v).shape[0] > 0
                               for v in f.values())
    return fn


def _no_nans(args):
    columns = args.get("columns")

    def fn(f: Frame) -> bool:
        for k, v in f.items():
            if columns is not None and k not in columns:
                continue
            a = np.asarray(v)
            if a.dtype.kind == "f" and np.isnan(a).any():
                return False
        return True
    return fn


def _column_range(args):
    column, lo, hi = args["column"], float(args["lo"]), float(args["hi"])

    def fn(f: Frame) -> bool:
        v = np.asarray(f[column])
        return bool(v.size) and float(v.min()) >= lo and float(v.max()) <= hi
    return fn


def _columns_required(args):
    required = list(args["columns"])

    def fn(f: Frame) -> bool:
        return all(c in f for c in required)
    return fn


register_rule("not_empty", _not_empty)
register_rule("no_nans", _no_nans)
register_rule("column_range", _column_range)
register_rule("columns_required", _columns_required)


# ------------------------------------------------------------- evaluation
def evaluate(contract: Contract, frame: Frame) -> Dict[str, str]:
    """Run every rule over the frame; returns ``{rule name: why}`` for the
    failures (empty dict = contract satisfied).  An erroring or unknown
    rule is a failure — enforcement fails closed."""
    failures: Dict[str, str] = {}
    for r in contract.rules:
        builder = _RULES.get(r.kind)
        if builder is None:
            failures[r.name] = f"unknown rule kind {r.kind!r}"
            continue
        try:
            if not bool(builder(r.args)(frame)):
                failures[r.name] = "failed"
        except Exception as e:  # noqa: BLE001 - fail closed, keep the why
            failures[r.name] = f"{type(e).__name__}: {e}"
    return failures


# ---------------------------------------------------------- serialization
def pack_contracts(contracts: Mapping[str, Contract]) -> bytes:
    return _pack({"version": 1,
                  "contracts": [contracts[t].to_obj()
                                for t in sorted(contracts)]})


def unpack_contracts(blob: bytes) -> Dict[str, Contract]:
    obj = _unpack(blob)
    out: Dict[str, Contract] = {}
    for c in obj.get("contracts", []):
        contract = Contract.from_obj(c)
        out[contract.table] = contract
    return out


# ------------------------------------------------------------ CLI parsing
def parse_rule_spec(spec: str) -> Rule:
    """``repro contract add`` rule syntax → :class:`Rule`.

        not_empty
        no_nans                         (all float columns)
        no_nans:colA,colB               (named columns only)
        column_range:col,lo,hi
        columns_required:colA,colB
    """
    kind, _, rest = spec.partition(":")
    kind = kind.strip()
    parts = [p.strip() for p in rest.split(",") if p.strip()]
    if kind == "not_empty":
        return rule("not_empty")
    if kind == "no_nans":
        return rule("no_nans", **({"columns": parts} if parts else {}))
    if kind == "column_range":
        if len(parts) != 3:
            raise ReproError(
                f"column_range needs col,lo,hi (got {spec!r})")
        return rule("column_range", column=parts[0],
                    lo=float(parts[1]), hi=float(parts[2]))
    if kind == "columns_required":
        if not parts:
            raise ReproError(f"columns_required needs columns (got {spec!r})")
        return rule("columns_required", columns=parts)
    raise ReproError(f"unknown contract rule kind {kind!r} in {spec!r}")
