"""Write-Audit-Publish (paper §5.5): branch → expectations → gated merge.

Expectations are named boolean functions over dataframes ("typically called
expectations... functions from dataframes to booleans").  In the training
integration they also run over *metric tables* (e.g. "loss is finite and
decreasing"), giving CI/CD semantics to model training itself.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .catalog import Catalog
from .errors import ExpectationFailed, TableNotFound, TransactionConflict
from .table import TableIO
from .txn import DEFAULT_MAX_ATTEMPTS

Frame = Mapping[str, np.ndarray]


@dataclass(frozen=True)
class Expectation:
    name: str
    table: str
    fn: Callable[[Frame], bool]
    description: str = ""


def expectation(table: str, *, name: Optional[str] = None,
                description: str = ""):
    """Decorator: ``@expectation('training_data')`` over a frame→bool fn."""

    def deco(fn: Callable[[Frame], bool]) -> Expectation:
        return Expectation(name or fn.__name__, table, fn, description)

    return deco


@dataclass
class AuditReport:
    branch: str
    commit: str
    passed: bool
    results: Dict[str, bool] = field(default_factory=dict)
    errors: Dict[str, str] = field(default_factory=dict)


def audit(catalog: Catalog, io: TableIO, branch: str,
          expectations: Sequence[Expectation]) -> AuditReport:
    """Run expectations against the branch head (the A of W-A-P)."""
    commit = catalog.head(branch)
    tables = catalog.tables(commit)  # at the pinned commit, not the name:
    # the report's commit and tables are guaranteed to describe one state
    results: Dict[str, bool] = {}
    errors: Dict[str, str] = {}
    cache: Dict[str, Dict[str, np.ndarray]] = {}
    for exp in expectations:
        try:
            if exp.table not in tables:
                raise TableNotFound(exp.table)
            if exp.table not in cache:
                cache[exp.table] = io.read(tables[exp.table])
            results[exp.name] = bool(exp.fn(cache[exp.table]))
        except Exception as e:  # an erroring expectation fails the audit
            results[exp.name] = False
            errors[exp.name] = f"{type(e).__name__}: {e}"
    return AuditReport(branch=branch, commit=commit,
                       passed=all(results.values()) if results else True,
                       results=results, errors=errors)


def audit_frames(expectations: Sequence[Expectation],
                 frames: Mapping[str, Frame], *,
                 context: str = "frames") -> AuditReport:
    """Run expectations over in-memory frames (no catalog read).

    The live-metrics variant of :func:`audit`: the serving canary gates a
    tag flip on metric buffers it just collected, without requiring them to
    be committed first.  The committed-table :func:`audit` remains the
    authoritative, replayable gate — this one trades that for immediacy."""
    results: Dict[str, bool] = {}
    errors: Dict[str, str] = {}
    for exp in expectations:
        try:
            if exp.table not in frames:
                raise TableNotFound(exp.table)
            results[exp.name] = bool(exp.fn(frames[exp.table]))
        except Exception as e:  # an erroring expectation fails the audit
            results[exp.name] = False
            errors[exp.name] = f"{type(e).__name__}: {e}"
    return AuditReport(branch=context, commit="",
                       passed=all(results.values()) if results else True,
                       results=results, errors=errors)


def publish(catalog: Catalog, io: TableIO, src_branch: str,
            expectations: Sequence[Expectation], *,
            dst_branch: str = "main", author: str = "system",
            clock=time.time, max_attempts: int = DEFAULT_MAX_ATTEMPTS) -> str:
    """The P of W-A-P: merge into ``dst`` only if the audit passes.

    This is the ONLY path that writes to a protected ``main`` — the audit
    report is stamped into the merge commit metadata so the publication is
    itself auditable.

    What gets published is **pinned to what was audited**: the audit stamp
    is a commit CAS'd against ``report.commit`` (the exact head the
    expectations ran over) and the merge source is the stamp digest, not
    the branch name.  A commit landing on the source branch between audit
    and merge therefore cannot ride through unaudited — the pinned stamp
    fails cleanly and publish re-runs the audit against the moved head
    (bounded by ``max_attempts``), which either vouches for the new data
    or refuses the publication."""
    for _ in range(max_attempts):
        report = audit(catalog, io, src_branch, expectations)
        if not report.passed:
            failed = sorted(n for n, ok in report.results.items() if not ok)
            raise ExpectationFailed(
                f"audit failed on {src_branch}: {failed} "
                f"(errors: {report.errors})")
        try:
            stamp = catalog.commit(
                src_branch, {},
                f"audit passed ({len(report.results)} expectations)",
                author=author,
                meta={"audit": {"results": report.results,
                                "commit": report.commit, "ts": clock()}},
                expected_head=report.commit,
            )
        except TransactionConflict:
            continue  # branch moved since the audit: re-audit the new head
        # merge the STAMP digest (immutable), never the branch name — a
        # post-stamp commit on src stays out of this publication
        return catalog.merge(stamp, dst_branch, author=author,
                             _wap_token=True)
    raise ExpectationFailed(
        f"could not publish {src_branch}: branch kept moving during "
        f"audit ({max_attempts} attempts)")


# ----------------------------------------------------------- common checks
def not_empty(table: str) -> Expectation:
    return Expectation(f"{table}_not_empty", table,
                       lambda f: all(v.shape[0] > 0 for v in f.values()),
                       "table has rows")


def no_nans(table: str, columns: Optional[Sequence[str]] = None) -> Expectation:
    def fn(f: Frame) -> bool:
        for k, v in f.items():
            if columns is not None and k not in columns:
                continue
            if np.asarray(v).dtype.kind == "f" and np.isnan(v).any():
                return False
        return True

    return Expectation(f"{table}_no_nans", table, fn, "no NaNs in float cols")


def column_range(table: str, column: str, lo: float, hi: float) -> Expectation:
    def fn(f: Frame) -> bool:
        v = np.asarray(f[column])
        return bool(v.size) and float(v.min()) >= lo and float(v.max()) <= hi

    return Expectation(f"{table}_{column}_in_[{lo},{hi}]", table, fn)
