"""repro.core — the paper's contribution: a content-addressed tensor lake with
Git semantics (Nessie-style catalog) and replayable functional-DAG pipelines.

Layering (Fig. 2 of the paper):
    in-memory columns  ⇄  tensorfile  ⇄  table snapshots  ⇄  catalog commits
                                          (Iceberg-like)      (Nessie-like)
plus the run ledger (immutable run_ids, replay) and write-audit-publish.
"""

from .catalog import (Catalog, Commit, remote_tracking_ref,
                      remote_tracking_tag_ref)
from .compact import (CompactionError, CompactionReport, compact_snapshot,
                      compact_table)
from .contracts import (CONTRACTS_TABLE, Contract, Rule, parse_rule_spec,
                        register_rule, rule)
from .errors import (AmbiguousRefUpdate, CodecUnavailable, CodeDrift,
                     ContractViolation, CycleError, ExpectationFailed,
                     MergeConflict, NodeExecutionError, ObjectNotFound,
                     PermissionDenied, RefConflict, RefNotFound, RemoteError,
                     ReproError, RunNotFound, SchemaError, SyncError,
                     TableNotFound, TransactionConflict)
from .exec import (Lease, LeaseBoard, WorkerService, run_status)
from .frame import Expr, col, lit, nrows, select, where
from .ledger import (ReplayReport, RunLedger, mesh_fingerprint, run_pipeline,
                     runtime_fingerprint)
from .pipeline import (ExecutionReport, Model, Node, NodeStat, Pipeline,
                       RunResult, code_hash_of, execute, is_cache_safe, model,
                       sql_model)
from .remote import (HTTPTransport, LoopbackTransport, RemoteServer,
                     RemoteStore, TieredStore, connect, serve_http)
from .runcache import CacheDemotionWarning, RunCache, node_key
from .s3 import S3Backend
from .s3stub import serve_s3
from .store import (GC_GENERATION_REF, ObjectStore, StoreBackend,
                    bump_generation, decode_frame, encode_frame,
                    ensure_generation, frame_raw, read_generation,
                    sha256_hex)
from .sigv4 import Credentials, SigV4Signer
from .sync import (MultiSyncReport, SyncReport, clone, commit_closure, pull,
                   pull_refs, push, push_fanout, push_refs)
from .table import (ManifestEntry, ManifestFile, Snapshot, TableIO,
                    zone_may_match)
from .tensorfile import ColumnSpec, Schema
from .txn import Transaction, changed_tables, rebase_append
from .wap import (AuditReport, Expectation, audit, column_range, expectation,
                  no_nans, not_empty, publish)


class Lake:
    """Convenience bundle: one object store + catalog + table IO + ledger.

    >>> lake = Lake("/tmp/my_lake")
    >>> lake.catalog.create_branch("richard.debug", "main", author="richard")

    With ``remote=`` the store becomes a :class:`TieredStore`: reads fault
    through to the remote tier with local write-back, so branch heads and
    warm run-cache entries published by another host are visible without an
    explicit pull (writes still land locally until pushed).
    """

    def __init__(self, root, *, protect_main: bool = True, clock=None,
                 remote=None):
        import time as _time

        clock = clock or _time.time
        self.store = ObjectStore(root) if remote is None \
            else TieredStore(ObjectStore(root), remote)
        self.catalog = Catalog(self.store, protect_main=protect_main,
                               clock=clock)
        self.io = TableIO(self.store)
        self.ledger = RunLedger(self.store, clock=clock)
        self.run_cache = RunCache(self.store, clock=clock)

    # thin facades used across examples / benchmarks -------------------------
    def write_table(self, branch: str, name: str, cols, *, author="system",
                    message=None) -> str:
        snap = self.io.write_snapshot(cols)
        self.catalog.commit(branch, {name: snap},
                            message or f"write {name}", author=author)
        return snap

    def read_table(self, ref: str, name: str, columns=None, where=None):
        return self.io.read(self.catalog.snapshot_of(ref, name), columns,
                            where=where)

    def run(self, pipeline: Pipeline, *, branch: str, author="system",
            config=None, seed=None, mesh=None, use_cache=True,
            jobs=None, executor="thread", **exec_opts) -> RunResult:
        return run_pipeline(pipeline, self.catalog, self.io, self.ledger,
                            branch=branch, author=author, config=config,
                            seed=seed, mesh=mesh, cache=self.run_cache,
                            use_cache=use_cache, jobs=jobs,
                            executor=executor, **exec_opts)

    def worker(self, pipelines, **kw) -> "WorkerService":
        """A :class:`WorkerService` over this lake's store — the in-process
        form of ``repro worker`` (tests, notebooks)."""
        return WorkerService(self.store, pipelines, **kw)

    def run_status(self, run_id: str):
        """Live/final per-node view of one execution (``repro status``)."""
        return run_status(self.store, run_id)

    def transaction(self, branch: str, *, author="system") -> "Transaction":
        """Open an optimistic read/write transaction on ``branch`` whose
        reads (through ``txn.io`` / ``txn.read``) build the declared set."""
        return self.catalog.transaction(branch, author=author, io=self.io)

    def replay(self, run_id: str, pipeline: Pipeline, *, branch: str,
               author="system", **kw) -> ReplayReport:
        kw.setdefault("cache", self.run_cache)
        return self.ledger.replay(run_id, pipeline, self.catalog, self.io,
                                  branch=branch, author=author, **kw)


__all__ = [
    "Lake", "Catalog", "Commit", "ObjectStore", "StoreBackend", "TableIO",
    "RemoteStore", "RemoteServer", "TieredStore", "LoopbackTransport",
    "HTTPTransport", "S3Backend", "serve_s3", "connect", "serve_http",
    "push", "pull", "clone",
    "push_refs", "pull_refs", "push_fanout", "SyncReport", "MultiSyncReport",
    "Credentials", "SigV4Signer",
    "commit_closure", "remote_tracking_ref", "remote_tracking_tag_ref",
    "decode_frame", "encode_frame", "frame_raw",
    "Snapshot",
    "ManifestEntry", "ManifestFile", "zone_may_match",
    "CompactionReport", "CompactionError", "compact_snapshot",
    "compact_table",
    "Schema", "ColumnSpec", "Pipeline", "Node", "Model",
    "model", "sql_model", "execute", "run_pipeline", "RunResult", "RunLedger",
    "RunCache", "node_key", "ExecutionReport", "NodeStat", "is_cache_safe",
    "CacheDemotionWarning", "Lease", "LeaseBoard", "WorkerService",
    "run_status", "NodeExecutionError",
    "Transaction", "changed_tables", "rebase_append",
    "Contract", "Rule", "rule", "register_rule", "parse_rule_spec",
    "CONTRACTS_TABLE",
    "ReplayReport", "Expectation", "expectation", "audit", "publish",
    "AuditReport", "not_empty", "no_nans", "column_range", "col", "lit",
    "Expr", "select", "where", "nrows", "sha256_hex", "code_hash_of",
    "mesh_fingerprint", "runtime_fingerprint",
    # errors
    "ReproError", "ObjectNotFound", "RefNotFound", "RefConflict",
    "TableNotFound", "SchemaError", "MergeConflict", "PermissionDenied",
    "CycleError", "ExpectationFailed", "CodeDrift", "RunNotFound",
    "RemoteError", "SyncError", "AmbiguousRefUpdate", "CodecUnavailable",
    "TransactionConflict", "ContractViolation",
]
