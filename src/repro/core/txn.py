"""Optimistic table-level transactions over the catalog (ROADMAP item 4).

The catalog's ref CAS protects the *ref*, not the *tables*: before this
layer, two writers committing to different tables on the same branch
collided at the ref level and one retried from scratch — a spurious
conflict that multiplies with writer count.  The fix is Iceberg-style
semantic conflict detection on top of ``core/table.py`` snapshots:

* a commit *declares* its read/write table set (writes are the keys of
  ``table_updates``; reads are captured by :class:`Transaction` or passed
  as ``read_tables=``);
* on a ref-level CAS miss the catalog **rebases**: it re-reads the moved
  head, checks that no declared table changed snapshot since the
  transaction's base, rebuilds the commit on the new head and retries the
  CAS (bounded attempts);
* only a *genuinely overlapping* snapshot movement raises
  :class:`~.errors.TransactionConflict` — disjoint writers never see a
  conflict at all.

The rebase engine itself lives in ``Catalog.commit``/``Catalog.merge``
(it needs commit plumbing); this module holds the shared policy knobs,
the declared-set conflict check, and the :class:`Transaction` façade that
captures read sets at the table-IO layer.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Set

import numpy as np

from .errors import SchemaError, TableNotFound
from .table import Snapshot, TableIO

#: bounded rebase attempts for an unpinned transaction.  Each failed CAS
#: means some *other* writer landed (system-wide progress), so exhaustion
#: signals pathological contention, not livelock; the bound keeps a
#: starved writer's failure loud instead of infinite.
DEFAULT_MAX_ATTEMPTS = 16


def changed_tables(base_tables: Mapping[str, str],
                   head_tables: Mapping[str, str],
                   declared: Iterable[str]) -> list:
    """Declared tables whose snapshot differs between the transaction's
    base commit and the (moved) head — the semantic conflict test.  A
    table changed and changed *back* compares equal (snapshot digests are
    content addresses): snapshot-level, not history-level, semantics."""
    return sorted(t for t in declared
                  if base_tables.get(t) != head_tables.get(t))


def rebase_append(io: TableIO, base: Optional[str], theirs: Optional[str],
                  ours: Optional[str]) -> Optional[str]:
    """Manifest-diff merge for two writers appending to the SAME table.

    ``base`` is the table's snapshot at the transaction's base commit,
    ``theirs`` what the moved head holds now, ``ours`` what this
    transaction staged.  When both sides are pure appends on ``base`` —
    their manifest lists extend base's verbatim, which the three-level
    hierarchy makes a cheap prefix check over manifest keys — the appends
    touch disjoint files by construction, and the merge is "their
    manifests + our new ones" as a fresh snapshot on their head.  Returns
    its digest, or None when the movement is NOT append/append (overwrite,
    compaction, delete, schema drift, or anything unreadable as a v0/v1
    snapshot — e.g. the ``__contracts__`` registry): the caller falls back
    to :class:`~.errors.TransactionConflict`, exactly as before this
    existed."""
    if base is None or theirs is None or ours is None:
        return None
    if ours == base:  # read-only declaration on a moved table: not a merge
        return None
    if theirs == base:  # head did not actually move this table
        return ours
    try:
        base_snap = io.load_snapshot(base)
        their_snap = io.load_snapshot(theirs)
        our_snap = io.load_snapshot(ours)
    except Exception:  # noqa: BLE001 - not snapshots (contracts registry…)
        return None
    base_keys = [m.key() for m in base_snap.manifests]

    def extends_base(snap: Snapshot) -> bool:
        keys = [m.key() for m in snap.manifests]
        return len(keys) >= len(base_keys) and keys[:len(base_keys)] == base_keys

    if not extends_base(their_snap) or not extends_base(our_snap):
        return None  # someone rewrote history: a genuine conflict
    ours_new = our_snap.manifests[len(base_keys):]
    if not ours_new:  # we appended nothing: their state already covers us
        return theirs
    try:
        their_snap.schema.check_compatible(our_snap.schema)
    except SchemaError:
        return None
    merged = Snapshot(
        schema=their_snap.schema,
        manifests=their_snap.manifests + ours_new,
        parent=theirs,
        op="append",
        seq=their_snap.seq + 1,
    )
    return io.store_snapshot(merged)


class Transaction:
    """One optimistic read/write transaction against a branch.

    Reads resolve against the transaction's *base* commit (the branch head
    at open time) — a stable snapshot view, like a repeatable-read
    database transaction — and are recorded in the read set.  Writes stage
    snapshots without touching the branch.  ``commit()`` hands the staged
    updates plus the declared read set to ``Catalog.commit``, which
    rebases over concurrent disjoint commits and raises
    :class:`~.errors.TransactionConflict` iff a declared table moved.

    >>> txn = lake.catalog.transaction("etl.daily", author="etl")
    >>> raw = txn.read("raw_events")                 # read-set capture
    >>> txn.write("daily_agg", aggregate(raw))
    >>> txn.commit("daily aggregation")              # rebases if needed

    The ``io`` attribute is a :class:`~.table.TableIO` whose reads are
    recorded too, so code that only receives the IO handle (pipeline
    nodes) still contributes to the read set.
    """

    def __init__(self, catalog, branch: str, *, author: str = "system",
                 io: Optional[TableIO] = None):
        self.catalog = catalog
        self.branch = branch
        self.author = author
        self.base = catalog.head(branch)
        self._base_tables: Dict[str, str] = catalog.tables(self.base)
        self._snap_to_table = {s: t for t, s in self._base_tables.items()}
        self.reads: Set[str] = set()
        self.writes: Dict[str, Optional[str]] = {}
        base_io = io or TableIO(catalog.store)
        self.io = base_io.with_read_recorder(self._record_snapshot_read)
        self.commit_digest: Optional[str] = None

    # ----------------------------------------------------------- read set
    def _record_snapshot_read(self, digest: str) -> None:
        table = self._snap_to_table.get(digest)
        if table is not None:
            self.reads.add(table)

    def snapshot_of(self, table: str) -> str:
        """Snapshot digest of ``table`` in this transaction's view (staged
        writes shadow the base).  Records the read."""
        self.reads.add(table)
        if table in self.writes:
            snap = self.writes[table]
            if snap is None:
                raise TableNotFound(f"{table!r} deleted in this transaction")
            return snap
        if table not in self._base_tables:
            raise TableNotFound(f"{table!r} not at {self.branch!r} base")
        return self._base_tables[table]

    def read(self, table: str,
             columns: Optional[Sequence[str]] = None,
             where=None) -> Dict[str, np.ndarray]:
        return self.io.read(self.snapshot_of(table), columns, where=where)

    # ---------------------------------------------------------- write set
    def write(self, table: str, cols: Mapping[str, np.ndarray], *,
              append: bool = False) -> str:
        """Stage a new snapshot for ``table`` (nothing moves on the branch
        until ``commit``).  ``append=True`` chains onto the table's
        current snapshot in this transaction's view."""
        parent = None
        if append:
            parent = self.writes.get(table, self._base_tables.get(table))
        snap = self.io.write_snapshot(
            cols, parent=parent, op="append" if parent else "overwrite")
        self.writes[table] = snap
        return snap

    def write_snapshot(self, table: str, snapshot_digest: str) -> None:
        """Stage an already-written snapshot (pipeline outputs)."""
        self.writes[table] = snapshot_digest

    def delete(self, table: str) -> None:
        self.writes[table] = None

    # -------------------------------------------------------------- commit
    def commit(self, message: str, *, meta=None, _wap_token: bool = False,
               max_attempts: int = DEFAULT_MAX_ATTEMPTS) -> str:
        """Land the staged writes.  Declared set = reads ∪ writes, checked
        from this transaction's base — a concurrent commit to any OTHER
        table is rebased over silently."""
        self.commit_digest = self.catalog.commit(
            self.branch, dict(self.writes), message, author=self.author,
            meta=meta, read_tables=sorted(self.reads - set(self.writes)),
            base=self.base, max_attempts=max_attempts,
            _wap_token=_wap_token)
        return self.commit_digest

    # transactions are explicit-commit: the context manager only scopes
    # the read/write capture, an un-committed exit discards the staging
    def __enter__(self) -> "Transaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None
