"""Remote object-store tier: the "S3 + Nessie service" half of the paper.

The local :class:`~repro.core.store.ObjectStore` makes a replay reproducible
*on the host that ran it*; this module is what makes it reproducible anywhere.
Three pieces:

``RemoteServer`` / transports
    A server speaking the :class:`~repro.core.store.StoreBackend` wire
    contract over msgpack request/response dicts.  The operations map 1:1
    onto an S3-style service (keys are the same ``objects/ab/cdef...``
    layout the filesystem store uses):

        ================  ===========================================
        wire op           S3/real-service equivalent
        ================  ===========================================
        put_object        PutObject (idempotent: content addressed)
        get_object        GetObject (digest-verified by the client)
        head_objects      batched HeadObject
        get_objects       batched GetObject (one frame per leaf chunk)
        put_objects       batched PutObject (one frame per leaf chunk)
        get_objects_…     …_encoded: batched GetObject of framed at-rest
                          payloads (compressed wire frames)
        put_objects_…     …_encoded: batched PutObject of framed payloads
                          (decoded + digest-verified server-side)
        has_chunks        chunk-level HeadObject: which content-defined
                          chunk hashes the server can already resolve
                          (bounded index over its own large blobs)
        put_objects_delta batched PutObject of delta *recipes* — literal
                          runs + references to chunks the server already
                          holds; reassembled, re-hashed per chunk and
                          digest-verified server-side.  Unresolvable
                          references answer "stale", never an error: the
                          client re-sends those blobs whole-frame
                          (see repro.core.delta)
        delete_object     DeleteObject (remote-side GC sweep; clients
                          must opt in with allow_delete=True)
        stat_object       HeadObject (size + Last-Modified — what the
                          GC grace window compares upload ages against)
        gc_mark           server-side GC mark: the server walks its OWN
                          refs/objects (no per-object wire reads), bumps
                          the generation token, stashes the live set
        gc_sweep          server-side sweep under a gc_mark token, with
                          the upload-age grace window applied locally
        list_objects      ListObjectsV2 w/ ContinuationToken
        get_ref/set_ref   tiny pointer objects
        cas_ref           conditional put (DynamoDB / If-Match)
        cas_refs          transactional multi-item conditional write
        list_refs         paged pointer listing (name, digest) pairs
        ================  ===========================================

    Two transports ship: ``LoopbackTransport`` (in-process, still goes
    through a full msgpack encode/decode so only wire-safe types survive)
    and ``HTTPTransport`` + :func:`serve_http` (stdlib http.server loopback
    — one POST endpoint carrying msgpack bodies).

``RemoteStore``
    The client: implements ``StoreBackend``, so catalogs, run caches,
    ledgers and the sync layer use a remote exactly like a local directory.
    Idempotent requests retry on transient transport faults.

``TieredStore``
    local→remote read-through with local write-back: ``get`` serves from
    the local tier, faults to the remote and persists the blob locally;
    refs read local-first with remote fallback; all writes land locally.
    A warm run-cache hit on host B can therefore reuse host A's node
    outputs without an explicit pull (see docs/remote_store.md for the
    trust model).
"""

from __future__ import annotations

import threading
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Set, Tuple)

import msgpack

from . import delta as _delta
from .errors import (AmbiguousRefUpdate, CodecUnavailable, ObjectNotFound,
                     RefConflict, RefNotFound, RemoteError, ReproError)
from .store import (ObjectStore, StoreBackend, decode_frame, frame_raw,
                    sha256_hex)

#: ref value meaning "must not exist" in wire CAS (msgpack has no Optional
#: on the sentinel side of If-Match semantics)
_ABSENT = ""


def _pack(obj) -> bytes:
    return msgpack.packb(obj, use_bin_type=True)


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


# --------------------------------------------------------------------- server
class RemoteServer:
    """Serves the wire contract over any :class:`StoreBackend` (usually a
    plain filesystem :class:`ObjectStore` — which is exactly what makes the
    S3-key layout claim true: the served tree IS the S3 key scheme)."""

    def __init__(self, store: StoreBackend):
        self.store = store
        # Pending server-side GC marks.  REAL marks (a generation bump
        # will follow with a sweep) are persisted in the served store
        # itself — mark blob in the object keyspace, ``gc/mark/<token>``
        # ref pointing at it — so a server restart between gc_mark and
        # gc_sweep no longer aborts the collection (any server instance
        # over the same store can finish it).  Bounded to the
        # ``_GC_MARK_KEEP`` most recent so a crashed GC client cannot
        # leak unbounded live sets.  DRY-RUN marks stay process-local:
        # a dry run must not write anything, so its token only has to
        # outlive the immediate dry sweep that consumes it.
        self._gc_marks: Dict[str, set] = {}
        self._gc_nonce = 0
        self._gc_lock = threading.Lock()
        # chunk hash → location over this server's large blobs: what lets
        # a sender ship delta recipes instead of whole frames.  Purely an
        # accelerator (bounded LRU, every hit re-verified) — an empty or
        # stale index costs wire bytes, never correctness.
        self.chunks = _delta.ChunkIndex()

    _GC_MARK_REF_PREFIX = "gc/mark/"
    _GC_MARK_KEEP = 4

    # ---------------------------------------------- persistent mark blobs
    def _pending_marks(self) -> List[Tuple[str, str]]:
        """(token, mark blob digest) of every persisted, unconsumed mark."""
        out = []
        for ref in self.store.iter_refs(self._GC_MARK_REF_PREFIX):
            try:
                out.append((ref[len(self._GC_MARK_REF_PREFIX):],
                            self.store.get_ref(ref)))
            except RefNotFound:  # consumed by a concurrent sweep
                continue
        return out

    def _drop_mark(self, token: str, digest: Optional[str]) -> None:
        """Consume a persisted mark: ref first (the consumption point),
        then the blob unless another pending mark shares it (identical
        live sets content-address to the same blob)."""
        try:
            self.store.delete_ref(self._GC_MARK_REF_PREFIX + token)
        except RefNotFound:
            pass
        if digest is not None and all(d != digest
                                      for _t, d in self._pending_marks()):
            self.store.delete_object(digest)

    # Each op returns a plain dict; errors are returned (not raised) so the
    # transport layer stays exception-free and HTTP responses stay 200.
    def handle(self, request: Dict[str, Any]) -> Dict[str, Any]:
        try:
            op = request.get("op")
            fn = getattr(self, f"_op_{op}", None)
            if fn is None:
                return {"error": "bad_request", "message": f"unknown op {op!r}"}
            return fn(request)
        except CodecUnavailable as e:
            # before ObjectNotFound (its superclass): the client falls back
            # to raw transfer on this one instead of treating it as missing
            return {"error": "codec_unavailable", "message": str(e)}
        except ObjectNotFound as e:
            return {"error": "object_not_found", "message": str(e)}
        except RefNotFound as e:
            return {"error": "ref_not_found", "message": str(e)}
        except RefConflict as e:
            return {"error": "ref_conflict", "message": str(e)}
        except (KeyError, TypeError, ValueError) as e:
            return {"error": "bad_request", "message": repr(e)}

    def handle_bytes(self, payload: bytes) -> bytes:
        """msgpack-framed entry point shared by every transport."""
        try:
            request = _unpack(payload)
        except Exception as e:  # noqa: BLE001 - malformed frame
            return _pack({"error": "bad_request", "message": repr(e)})
        return _pack(self.handle(request))

    # objects -----------------------------------------------------------
    def _index_blob(self, digest: str, data: bytes) -> None:
        """Feed the chunk index on arrival — only blobs big enough that a
        future delta against them could beat a whole frame."""
        if len(data) >= _delta.DELTA_MIN_BYTES:
            self.chunks.add_blob(digest, data)

    def _op_put_object(self, req):
        data = req["data"]
        digest = req["digest"]
        if sha256_hex(data) != digest:
            return {"error": "bad_request",
                    "message": f"content does not hash to {digest}"}
        # idempotent: ObjectStore.put dedups on existing digests
        got = self.store.put(data)
        self._index_blob(digest, data)
        return {"digest": got}

    def _op_get_object(self, req):
        return {"data": self.store.get(req["digest"])}

    def _op_head_objects(self, req):
        return {"present": sorted(self.store.has_many(req["digests"]))}

    def _op_get_objects(self, req):
        # batched GetObject: one frame carries a whole leaf chunk, so a
        # closure transfer pays one round-trip per chunk, not per blob
        return {"objects": [[d, self.store.get(d)] for d in req["digests"]]}

    def _op_put_objects(self, req):
        digests = []
        for digest, data in req["objects"]:
            if sha256_hex(data) != digest:
                return {"error": "bad_request",
                        "message": f"content does not hash to {digest}"}
            digests.append(self.store.put(data))
            self._index_blob(digest, data)
        return {"digests": digests}

    def _op_get_objects_encoded(self, req):
        # batched GetObject of FRAMED payloads: a blob compressed at rest
        # on the serving store crosses the wire in that form — the client
        # decodes once (verification + accounting) and never recompresses
        get_encoded = getattr(self.store, "get_encoded", None)
        if get_encoded is None:  # backend without at-rest framing
            return {"objects": [[d, frame_raw(self.store.get(d))]
                                for d in req["digests"]]}
        return {"objects": [[d, get_encoded(d)] for d in req["digests"]]}

    def _op_put_objects_encoded(self, req):
        # decode HERE (server-side verification was always part of this
        # op's contract) so the raw bytes can also feed the chunk index —
        # these are exactly the blobs a follow-up checkpoint push will
        # want to delta against
        put_many_encoded = getattr(self.store, "put_many_encoded", None)
        digests = []
        for digest, payload in req["objects"]:
            data = decode_frame(payload, what="encoded payload")
            if sha256_hex(data) != digest:
                return {"error": "bad_request",
                        "message": f"payload does not hash to {digest}"}
            if put_many_encoded is not None:
                # store the original frame (compression already paid at
                # the source); the digest hint skips stores' re-decode
                # where they honor it
                got = put_many_encoded([payload], digests=[digest])[0]
            else:
                got = self.store.put(data)
            if got != digest:
                return {"error": "bad_request",
                        "message": f"store acknowledged {got}, "
                                   f"expected {digest}"}
            self._index_blob(digest, data)
            digests.append(got)
        return {"digests": digests}

    def _op_has_chunks(self, req):
        # chunk-level has_many: the one round-trip that decides how much
        # of each blob a delta push can leave out
        return {"present": sorted(self.chunks.has(req["hashes"]))}

    def _op_put_objects_delta(self, req):
        # batched delta put: reassemble each recipe against the chunk
        # index + our own store, verify chunk-by-chunk AND whole-blob,
        # store like any other put.  A reference we can no longer resolve
        # (evicted index entry, GC'd blob) makes that blob "stale" — the
        # client re-sends it whole-frame; it is never an error.
        digests: List[str] = []
        stale: List[str] = []
        blob_cache: Dict[str, bytes] = {}
        for digest, recipe in req["objects"]:
            try:
                data = _delta.assemble(recipe, self.chunks, self.store.get,
                                       blob_cache)
            except ObjectNotFound:
                stale.append(digest)
                continue
            if sha256_hex(data) != digest:
                return {"error": "bad_request",
                        "message": f"delta recipe does not reassemble "
                                   f"to {digest}"}
            self.store.put(data)
            self._index_blob(digest, data)
            digests.append(digest)
        return {"digests": digests, "stale": stale}

    def _op_delete_object(self, req):
        # remote-side GC sweep (repro gc --remote): the only mutation of
        # the object keyspace the protocol exposes — clients must opt in
        # (RemoteStore(allow_delete=True)), so a tier-mounted client can
        # still never collect from the shared remote by accident
        return {"deleted": bool(self.store.delete_object(req["digest"]))}

    def _op_list_objects(self, req):
        page, nxt = self.store.list_objects(
            page_token=req.get("token") or None,
            limit=int(req.get("limit") or 1000))
        return {"digests": page, "next": nxt}

    def _op_size_object(self, req):
        return {"size": self.store.size(req["digest"])}

    def _op_stat_object(self, req):
        # size + upload mtime in one round-trip: what a client-side GC
        # sweep needs per candidate to honor the --prune-age grace window
        # against a server without gc_mark/gc_sweep
        digest = req["digest"]
        return {"size": self.store.size(digest),
                "mtime": float(self.store.mtime(digest))}

    def _op_touch_objects(self, req):
        # batched mtime refresh (sync touch-on-dedup): resets the grace-
        # window clock on objects a push deduplicated against, so they
        # cannot age out while the rest of the closure uploads
        touch = getattr(self.store, "touch_many", None)
        if touch is None:  # backend without cheap touch: report 0
            return {"touched": 0}
        return {"touched": int(touch(req["digests"]))}

    # ----------------------------------------------------- server-side GC
    def _op_gc_mark(self, req):
        # the whole mark phase runs HERE, over the server's own store: no
        # per-object wire reads.  Bumps the GC generation token first (not
        # on dry runs — nothing will be deleted) so concurrent pushes that
        # captured the old token fail their cas_refs cleanly.
        from .gc import mark_live
        from .store import bump_generation

        dry_run = bool(req.get("dry_run"))
        if dry_run:
            # a nonce token, NOT the shared generation: a dry run must
            # neither bump the generation nor collide with (and later
            # consume) a real mark pending its sweep.  Kept in process
            # memory — a dry run writes nothing to the store.
            with self._gc_lock:
                self._gc_nonce += 1
                token = f"dry-{self._gc_nonce}"
        else:
            token = bump_generation(self.store)
        live = mark_live(self.store, drop_cache=bool(req.get("drop_cache")),
                         dry_run=dry_run)
        if dry_run:
            with self._gc_lock:
                self._gc_marks[token] = live
                while len(self._gc_marks) > self._GC_MARK_KEEP:
                    self._gc_marks.pop(next(iter(self._gc_marks)))
        else:
            # persist the mark: blob in the object keyspace, consumed by
            # the sweep — which may run on a different server instance
            digest = self.store.put(_pack({"live": sorted(live)}))
            self.store.set_ref(self._GC_MARK_REF_PREFIX + token, digest)
            # prune abandoned marks beyond the newest _GC_MARK_KEEP
            # (generation tokens are monotonically increasing integers)
            pending = sorted(self._pending_marks(),
                             key=lambda td: int(td[0]))
            for old_token, old_digest in pending[:-self._GC_MARK_KEEP]:
                self._drop_mark(old_token, old_digest)
        return {"generation": token, "live": len(live)}

    def _op_gc_sweep(self, req):
        from .gc import sweep

        generation = req["generation"]
        with self._gc_lock:
            live = self._gc_marks.pop(generation, None)
        mark_digest: Optional[str] = None
        if live is None:  # not a dry token: look up the persisted mark
            try:
                mark_digest = self.store.get_ref(
                    self._GC_MARK_REF_PREFIX + generation)
                live = set(_unpack(self.store.get(mark_digest))["live"])
            except RefNotFound:
                return {"error": "bad_request",
                        "message": f"unknown gc generation {generation!r} "
                                   "(run gc_mark first)"}
            except ObjectNotFound:
                self._drop_mark(generation, None)
                return {"error": "bad_request",
                        "message": f"gc mark {generation!r} expired "
                                   "(collected by a concurrent sweep); "
                                   "run gc_mark again"}
        # pending mark blobs are GC bookkeeping, not garbage: keep every
        # one (including ours) out of this sweep's candidate set
        keep = live | {d for _t, d in self._pending_marks()}
        swept, freed, young = sweep(
            self.store, keep,
            prune_age=float(req.get("prune_age") or 0.0),
            dry_run=bool(req.get("dry_run")))
        if mark_digest is not None and not bool(req.get("dry_run")):
            self._drop_mark(generation, mark_digest)
        return {"swept": swept, "bytes_freed": freed,
                "skipped_young": young}

    # refs --------------------------------------------------------------
    def _op_get_ref(self, req):
        return {"digest": self.store.get_ref(req["name"])}

    def _op_set_ref(self, req):
        self.store.set_ref(req["name"], req["digest"])
        return {}

    def _op_cas_ref(self, req):
        expected = req.get("expected", _ABSENT)
        self.store.cas_ref(req["name"],
                           None if expected == _ABSENT else expected,
                           req["new"])
        return {}

    def _op_cas_refs(self, req):
        # server-side multi-ref CAS: the whole batch commits or none of it
        # does, under the backing store's ref guard (multi-ref push atomicity
        # holds even with two servers fronting one tree)
        self.store.cas_refs([
            (name, None if expected == _ABSENT else expected, new)
            for name, expected, new in req["updates"]])
        return {}

    def _op_delete_ref(self, req):
        self.store.delete_ref(req["name"])
        return {}

    def _op_list_refs(self, req):
        page, nxt = self.store.list_refs(
            req.get("prefix") or "",
            page_token=req.get("token") or None,
            limit=int(req.get("limit") or 1000))
        return {"refs": [[n, d] for n, d in page], "next": nxt}


# ----------------------------------------------------------------- transports
class LoopbackTransport:
    """In-process transport.  Still round-trips through msgpack so requests
    are held to exactly what the wire can carry."""

    def __init__(self, server: RemoteServer):
        self.server = server

    def request(self, payload: bytes) -> bytes:
        return self.server.handle_bytes(payload)

    def close(self) -> None:
        pass


class HTTPTransport:
    """Client side of the HTTP loopback: POST msgpack frames to ``/rpc``.

    Connections are per-thread (http.client is not thread-safe) so a
    ``--jobs N`` executor can fault blobs concurrently through one store.
    """

    def __init__(self, url: str, *, timeout: float = 30.0):
        import urllib.parse

        parsed = urllib.parse.urlsplit(url)
        if parsed.scheme not in ("http", "https"):
            raise ValueError(f"unsupported scheme {parsed.scheme!r}")
        self.scheme = parsed.scheme
        self.host = parsed.hostname or "127.0.0.1"
        self.port = parsed.port or (443 if parsed.scheme == "https" else 80)
        self.timeout = timeout
        self._local = threading.local()

    def _conn(self):
        conn = getattr(self._local, "conn", None)
        if conn is None:
            import http.client

            cls = (http.client.HTTPSConnection if self.scheme == "https"
                   else http.client.HTTPConnection)
            conn = cls(self.host, self.port, timeout=self.timeout)
            self._local.conn = conn
        return conn

    def request(self, payload: bytes) -> bytes:
        conn = self._conn()
        try:
            conn.request("POST", "/rpc", body=payload,
                         headers={"Content-Type": "application/x-msgpack",
                                  "Content-Length": str(len(payload))})
            resp = conn.getresponse()
            body = resp.read()
            if resp.status != 200:
                raise RemoteError(f"HTTP {resp.status} from remote")
            return body
        except RemoteError:
            # drop the (possibly wedged) connection; retry policy lives in
            # RemoteStore, not here
            self.close()
            raise
        except Exception as e:  # http.client + socket raise a small zoo;
            # normalize to RemoteError so RemoteStore's idempotent-op retry
            # sees every transient fault (ECONNREFUSED, ECONNRESET, ...)
            self.close()
            raise RemoteError(f"transport failure: {e!r}") from e

    def close(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            try:
                conn.close()
            finally:
                self._local.conn = None


def serve_http(store: StoreBackend, *, host: str = "127.0.0.1",
               port: int = 0):
    """Start a loopback HTTP server for ``store`` on a daemon thread.

    Returns ``(httpd, url)``; ``port=0`` picks a free port.  Call
    ``httpd.shutdown()`` to stop (tests) or ``httpd.serve_forever()`` is
    already running so just keep the process alive (``repro serve``).
    """
    import http.server

    server = RemoteServer(store)

    class Handler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def do_POST(self):  # noqa: N802 - stdlib naming
            if self.path != "/rpc":
                self.send_error(404)
                return
            length = int(self.headers.get("Content-Length", 0))
            body = server.handle_bytes(self.rfile.read(length))
            self.send_response(200)
            self.send_header("Content-Type", "application/x-msgpack")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # quiet: tests run many requests
            pass

    httpd = http.server.ThreadingHTTPServer((host, port), Handler)
    httpd.daemon_threads = True
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    url = f"http://{httpd.server_address[0]}:{httpd.server_address[1]}"
    return httpd, url


# ----------------------------------------------------------------- the client
_RETRYABLE_OPS = frozenset({
    # all idempotent: re-sending after an ambiguous failure cannot corrupt
    # state.  cas_ref / cas_refs are deliberately NOT here — a retry after
    # a success that was lost in transit would double-apply the swap.
    "put_object", "get_object", "head_objects", "list_objects",
    "get_objects", "put_objects",
    "get_objects_encoded", "put_objects_encoded", "delete_object",
    # delta ops are idempotent too: has_chunks reads, and re-applying a
    # delta put re-stores the same content-addressed blobs
    "has_chunks", "put_objects_delta",
    "size_object", "stat_object", "touch_objects", "get_ref", "set_ref",
    "delete_ref", "list_refs",
    # gc_mark re-marks from scratch on retry (the superseded mark is
    # discarded server-side); gc_sweep is NOT retryable — a sweep whose
    # reply was lost consumed its mark, and a blind re-send would race
    # whatever uploads happened since
    "gc_mark",
})

#: non-idempotent ref updates: a transport fault after the request may have
#: been delivered leaves the ref state UNKNOWN — surfaced as
#: :class:`AmbiguousRefUpdate`, never as a plain failure (a "failed" push
#: could otherwise have silently succeeded; see docs/remote_store.md)
_AMBIGUOUS_OPS = frozenset({"cas_ref", "cas_refs"})


class RemoteStore:
    """StoreBackend client over a transport — a drop-in store replacement.

    >>> remote = RemoteStore(LoopbackTransport(RemoteServer(ObjectStore(p))))
    >>> remote.put(b"blob")  # content-addressed PUT over the wire

    ``allow_delete`` gates :meth:`delete_object`: remote objects are
    immutable to ordinary clients (a tier-mounted lake must never collect
    from the shared remote); only an explicit remote-side GC run
    (``repro gc --remote``) opens the sweep path.
    """

    def __init__(self, transport, *, retries: int = 2,
                 allow_delete: bool = False):
        self.transport = transport
        self.retries = retries
        self.allow_delete = allow_delete
        #: None = unknown, False = server predates the encoded wire ops
        self._encoded_ops: Optional[bool] = None
        #: None = unknown, False = server predates the delta wire ops
        self._delta_ops: Optional[bool] = None

    # ------------------------------------------------------------ plumbing
    def _call(self, op: str, **kwargs) -> Dict[str, Any]:
        request = {"op": op, **kwargs}
        payload = _pack(request)
        attempts = 1 + (self.retries if op in _RETRYABLE_OPS else 0)
        last: Optional[Exception] = None
        for _ in range(attempts):
            try:
                reply = _unpack(self.transport.request(payload))
                break
            except RemoteError as e:
                last = e
        else:
            if op in _AMBIGUOUS_OPS:
                raise AmbiguousRefUpdate(
                    f"{op}: transport failed after the update may have "
                    "been delivered; remote ref state is unknown — "
                    "re-read to resolve") from last
            raise RemoteError(f"{op}: transport failed after "
                              f"{attempts} attempts") from last
        if not isinstance(reply, dict):
            raise RemoteError(f"{op}: malformed reply from server "
                              f"({type(reply).__name__})")
        err = reply.get("error")
        if err:
            msg = reply.get("message", "")
            if err == "object_not_found":
                raise ObjectNotFound(msg)
            if err == "ref_not_found":
                raise RefNotFound(msg)
            if err == "ref_conflict":
                raise RefConflict(msg)
            if err == "codec_unavailable":
                raise CodecUnavailable(msg)
            raise RemoteError(f"{op}: {err}: {msg}")
        return reply

    def close(self) -> None:
        self.transport.close()

    # ------------------------------------------------------------- objects
    def put(self, data: bytes) -> str:
        digest = sha256_hex(data)
        return self._call("put_object", digest=digest, data=data)["digest"]

    def get(self, digest: str) -> bytes:
        data = self._call("get_object", digest=digest)["data"]
        if sha256_hex(data) != digest:  # never trust the wire
            raise ObjectNotFound(f"digest mismatch for {digest} from remote")
        return data

    def has(self, digest: str) -> bool:
        return bool(self.has_many([digest]))

    def has_many(self, digests: Iterable[str]) -> Set[str]:
        digests = list(digests)
        if not digests:
            return set()
        return set(self._call("head_objects", digests=digests)["present"])

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        digests = list(digests)
        if not digests:
            return {}
        reply = self._call("get_objects", digests=digests)
        out: Dict[str, bytes] = {}
        for digest, data in reply["objects"]:
            if sha256_hex(data) != digest:  # never trust the wire
                raise ObjectNotFound(
                    f"digest mismatch for {digest} from remote")
            out[digest] = data
        missing = [d for d in digests if d not in out]
        if missing:
            raise ObjectNotFound(
                f"remote returned {len(out)}/{len(digests)} objects "
                f"(first missing: {missing[0]})")
        return out

    def put_many(self, blobs: Sequence[bytes]) -> List[str]:
        blobs = list(blobs)
        if not blobs:
            return []
        items = [[sha256_hex(b), b] for b in blobs]
        digests = list(self._call("put_objects", objects=items)["digests"])
        if digests != [d for d, _b in items]:
            raise RemoteError("put_objects: server acknowledged different "
                              "digests than were sent")
        return digests

    def size(self, digest: str) -> int:
        return self._call("size_object", digest=digest)["size"]

    def mtime(self, digest: str) -> float:
        """Upload mtime over the wire (``stat_object``).  Raises
        :class:`RemoteError` ("unknown op") against a server predating the
        op — the GC sweep treats that as "no age data" and degrades, with
        a warning, to the legacy sweep-everything behavior."""
        return self.stat(digest)[1]

    def stat(self, digest: str) -> Tuple[int, float]:
        """``(size, mtime)`` in one ``stat_object`` round-trip — the
        per-candidate cost of a client-side grace-window sweep."""
        reply = self._call("stat_object", digest=digest)
        return int(reply["size"]), float(reply["mtime"])

    def delete_object(self, digest: str) -> bool:
        if not self.allow_delete:
            raise RemoteError(
                "remote objects are immutable to this client; open the "
                "remote with allow_delete=True (repro gc --remote) to "
                "run a remote-side sweep")
        return bool(self._call("delete_object", digest=digest)["deleted"])

    def touch_many(self, digests: Sequence[str]) -> int:
        """Batched remote mtime refresh (sync touch-on-dedup).  Best
        effort by contract: a server predating ``touch_objects`` answers
        "unknown op" and this degrades to 0 touched — the GC generation
        token still protects such pushes, just via retry instead."""
        digests = list(digests)
        if not digests:
            return 0
        try:
            return int(self._call("touch_objects",
                                  digests=digests)["touched"])
        except RemoteError as e:
            if self._is_unknown_op(e):
                return 0
            raise

    # ------------------------------------------------------ server-side GC
    def gc_mark(self, *, drop_cache: bool = False,
                dry_run: bool = False) -> Tuple[str, int]:
        """Run the GC mark phase ON the server (its own refs, its own
        store — zero per-object wire reads).  Returns ``(generation,
        live_count)``; hand the token to :meth:`gc_sweep`.  Gated on
        ``allow_delete`` like the sweep itself: marking bumps the shared
        generation token, which fails concurrent pushes' ref updates —
        not something a read-only tier client should be able to do.
        Dry runs neither bump nor delete, so they need no opt-in."""
        if not self.allow_delete and not dry_run:
            raise RemoteError(
                "remote GC requires a client opened with allow_delete="
                "True (repro gc --remote)")
        reply = self._call("gc_mark", drop_cache=drop_cache,
                           dry_run=dry_run)
        return str(reply["generation"]), int(reply["live"])

    def gc_sweep(self, generation: str, *, prune_age: float = 0.0,
                 dry_run: bool = False) -> Tuple[int, int, int]:
        """Sweep server-side under a mark token from :meth:`gc_mark`.
        Returns ``(swept, bytes_freed, skipped_young)``."""
        if not self.allow_delete and not dry_run:
            raise RemoteError(
                "remote GC requires a client opened with allow_delete="
                "True (repro gc --remote)")
        reply = self._call("gc_sweep", generation=generation,
                           prune_age=prune_age, dry_run=dry_run)
        return (int(reply["swept"]), int(reply["bytes_freed"]),
                int(reply["skipped_young"]))

    # -------------------------------------------------- encoded payloads
    def _supports_encoded(self) -> bool:
        return self._encoded_ops is not False

    @staticmethod
    def _is_unknown_op(e: RemoteError) -> bool:
        return "bad_request" in str(e) and "unknown op" in str(e)

    def get_encoded(self, digest: str) -> bytes:
        return self.get_many_encoded([digest])[digest]

    def put_encoded(self, payload: bytes) -> str:
        return self.put_many_encoded([payload])[0]

    def _encoded_unsupported(self, e: Optional[RemoteError] = None):
        """A server predating the encoded wire ops answers "unknown op":
        remember that and surface :class:`CodecUnavailable`, the same
        signal a codec mismatch sends — callers (the transfer engine)
        respond identically by re-sending raw, and the accounting then
        reflects the raw bytes that actually crossed the wire."""
        self._encoded_ops = False
        raise CodecUnavailable(
            "server predates the encoded wire ops") from e

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        """Batched fetch of framed payloads (compressed wire frames).
        The caller decodes + digest-verifies (``decode_frame``)."""
        digests = list(digests)
        if not digests:
            return {}
        if not self._supports_encoded():
            self._encoded_unsupported()
        try:
            reply = self._call("get_objects_encoded", digests=digests)
        except RemoteError as e:
            if self._is_unknown_op(e):
                self._encoded_unsupported(e)
            raise
        out = {d: payload for d, payload in reply["objects"]}
        missing = [d for d in digests if d not in out]
        if missing:
            raise ObjectNotFound(
                f"remote returned {len(out)}/{len(digests)} encoded "
                f"objects (first missing: {missing[0]})")
        return out

    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]:
        payloads = list(payloads)
        if not payloads:
            return []
        if not self._supports_encoded():
            self._encoded_unsupported()
        if digests is not None and len(digests) == len(payloads):
            # caller already decoded + verified (the transfer engine does);
            # the server decodes and re-verifies every payload regardless,
            # so skipping the redundant local decode loses no checking
            items = [[d, p] for d, p in zip(digests, payloads)]
        else:
            items = [[sha256_hex(decode_frame(p, what="encoded payload")), p]
                     for p in payloads]
        try:
            reply = self._call("put_objects_encoded", objects=items)
        except RemoteError as e:
            if self._is_unknown_op(e):
                self._encoded_unsupported(e)
            raise
        digests = list(reply["digests"])
        if digests != [d for d, _p in items]:
            raise RemoteError(
                "put_objects_encoded: server acknowledged different "
                "digests than were sent")
        return digests

    # ------------------------------------------------------- delta frames
    def _supports_delta(self) -> bool:
        """False once the server has answered "unknown op" for a delta op.
        Unlike the encoded-payload downgrade (which must redo the transfer
        raw, so it raises), delta degrades SILENTLY: a whole-frame put was
        always going to happen anyway, the delta path only tries to shrink
        it first."""
        return self._delta_ops is not False

    def has_chunks(self, hashes: Sequence[str]) -> Set[str]:
        """Which content-defined chunk hashes the server can resolve.
        Empty set against an old server (after one "unknown op" probe) —
        the sender then finds nothing to reference and ships whole frames,
        which is exactly the downgrade semantics we want."""
        hashes = list(hashes)
        if not hashes or not self._supports_delta():
            return set()
        try:
            reply = self._call("has_chunks", hashes=hashes)
        except RemoteError as e:
            if self._is_unknown_op(e):
                self._delta_ops = False
                return set()
            raise
        self._delta_ops = True
        return set(reply["present"])

    def put_objects_delta(self, items: Sequence[Tuple[str, list]]
                          ) -> Tuple[List[str], List[str]]:
        """Batched delta put → ``(stored digests, stale digests)``.

        Stale = the server could no longer resolve a referenced chunk
        (index eviction / concurrent GC); the caller re-sends those blobs
        whole-frame.  Against an old server every blob is reported stale —
        same re-send path, no special casing."""
        items = list(items)
        if not items:
            return [], []
        if not self._supports_delta():
            return [], [d for d, _r in items]
        try:
            reply = self._call("put_objects_delta",
                               objects=[[d, r] for d, r in items])
        except RemoteError as e:
            if self._is_unknown_op(e):
                self._delta_ops = False
                return [], [d for d, _r in items]
            raise
        stored = list(reply["digests"])
        stale = list(reply.get("stale") or [])
        sent = {d for d, _r in items}
        if not set(stored) | set(stale) >= sent:
            raise RemoteError(
                "put_objects_delta: server reply does not account for "
                "every blob sent")
        return stored, stale

    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000
                     ) -> Tuple[List[str], Optional[str]]:
        reply = self._call("list_objects", token=page_token or "",
                           limit=limit)
        return list(reply["digests"]), reply.get("next") or None

    def iter_objects(self) -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_objects(page_token=token)
            yield from page
            if token is None:
                return

    # ---------------------------------------------------------------- refs
    def set_ref(self, name: str, digest: str) -> None:
        self._call("set_ref", name=name, digest=digest)

    def get_ref(self, name: str) -> str:
        return self._call("get_ref", name=name)["digest"]

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        self._call("cas_ref", name=name,
                   expected=_ABSENT if expected is None else expected,
                   new=new)

    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None:
        self._call("cas_refs", updates=[
            [name, _ABSENT if expected is None else expected, new]
            for name, expected, new in updates])

    def delete_ref(self, name: str) -> None:
        self._call("delete_ref", name=name)

    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]:
        reply = self._call("list_refs", prefix=prefix,
                           token=page_token or "", limit=limit)
        return [(n, d) for n, d in reply["refs"]], reply.get("next") or None

    def iter_refs(self, prefix: str = "") -> Iterator[str]:
        token: Optional[str] = None
        while True:
            page, token = self.list_refs(prefix, page_token=token)
            for name, _digest in page:
                yield name
            if token is None:
                return


# -------------------------------------------------------------------- tiering
class TieredStore:
    """local→remote read-through with local write-back.

    * ``get``: local hit, else fetch from the remote, persist locally
      (write-back), return — so a blob is paid for once per host;
    * ``has``/``has_many``: local first, remainder asked remotely;
    * refs: read local-first with remote fallback (a run-cache key that only
      host A has still hits on host B); every write lands locally only —
      publishing to the remote is an explicit ``push``, never a side effect;
    * enumeration (``iter_objects``/``list_objects``/``delete_object``) is
      local-tier only: GC sweeps the cache tier, never the shared remote.
    """

    def __init__(self, local: ObjectStore, remote: StoreBackend):
        self.local = local
        self.remote = remote

    @property
    def root(self):
        return self.local.root

    # ------------------------------------------------------------- objects
    def put(self, data: bytes) -> str:
        return self.local.put(data)

    def get(self, digest: str) -> bytes:
        try:
            return self.local.get(digest)
        except ObjectNotFound:
            data = self.remote.get(digest)
            self.local.put(data)  # write-back: next read is local
            return data

    def has(self, digest: str) -> bool:
        return self.local.has(digest) or self.remote.has(digest)

    def has_many(self, digests: Iterable[str]) -> Set[str]:
        digests = list(digests)
        present = self.local.has_many(digests)
        rest = [d for d in digests if d not in present]
        if rest:
            present |= self.remote.has_many(rest)
        return present

    def get_many(self, digests: Sequence[str]) -> Dict[str, bytes]:
        out: Dict[str, bytes] = {}
        rest: List[str] = []
        for d in digests:
            try:
                out[d] = self.local.get(d)
            except ObjectNotFound:
                rest.append(d)
        if rest:
            fetched = self.remote.get_many(rest)
            for d, data in fetched.items():
                self.local.put(data)  # write-back, same as single get
                out[d] = data
        return out

    def put_many(self, blobs: Sequence[bytes]) -> List[str]:
        return self.local.put_many(blobs)

    def size(self, digest: str) -> int:
        try:
            return self.local.size(digest)
        except ObjectNotFound:
            return self.remote.size(digest)

    def mtime(self, digest: str) -> float:
        try:
            return self.local.mtime(digest)
        except ObjectNotFound:
            return self.remote.mtime(digest)

    def stat(self, digest: str) -> Tuple[int, float]:
        try:
            return self.local.stat(digest)
        except ObjectNotFound:
            return self.remote.stat(digest)

    def delete_object(self, digest: str) -> bool:
        return self.local.delete_object(digest)

    def touch_many(self, digests: Sequence[str]) -> int:
        # writes land locally, so the local tier is what a local GC would
        # sweep — touch there; never mutate the shared remote's clocks
        # from a tier mount
        return self.local.touch_many(list(digests))

    # -------------------------------------------------- encoded payloads
    def _supports_encoded(self) -> bool:
        """Forward the mounted remote's capability, so the transfer
        engine's encoded-path kill switch sees through the tier."""
        supports = getattr(self.remote, "_supports_encoded", None)
        return True if supports is None else supports()

    def get_encoded(self, digest: str) -> bytes:
        try:
            return self.local.get_encoded(digest)
        except ObjectNotFound:
            payload = self.remote.get_encoded(digest)
            self.local.put_encoded(payload)  # write-back, compressed form
            return payload

    def put_encoded(self, payload: bytes) -> str:
        return self.local.put_encoded(payload)

    def get_many_encoded(self, digests: Sequence[str]) -> Dict[str, bytes]:
        out: Dict[str, bytes] = {}
        rest: List[str] = []
        for d in digests:
            try:
                out[d] = self.local.get_encoded(d)
            except ObjectNotFound:
                rest.append(d)
        if rest:
            fetched = self.remote.get_many_encoded(rest)
            for d, payload in fetched.items():
                self.local.put_encoded(payload)
                out[d] = payload
        return out

    def put_many_encoded(self, payloads: Sequence[bytes],
                         digests: Optional[Sequence[str]] = None
                         ) -> List[str]:
        return self.local.put_many_encoded(payloads, digests=digests)

    def iter_objects(self) -> Iterator[str]:
        return self.local.iter_objects()

    def list_objects(self, *, page_token: Optional[str] = None,
                     limit: int = 1000):
        return self.local.list_objects(page_token=page_token, limit=limit)

    # ---------------------------------------------------------------- refs
    def set_ref(self, name: str, digest: str) -> None:
        self.local.set_ref(name, digest)

    def get_ref(self, name: str) -> str:
        try:
            return self.local.get_ref(name)
        except RefNotFound:
            return self.remote.get_ref(name)

    def cas_ref(self, name: str, expected: Optional[str], new: str) -> None:
        # CAS against the *tiered* view (a branch head may only exist
        # remotely yet) but always write locally — under the local store's
        # cross-process ref guard, so two processes sharing one lake
        # directory cannot both win (same linearizability as the plain
        # ObjectStore.cas_ref).
        with self.local.ref_guard():
            try:
                current: Optional[str] = self.get_ref(name)
            except RefNotFound:
                current = None
            if current != expected:
                raise RefConflict(
                    f"ref {name}: expected {expected!r}, found {current!r}")
            self.local.set_ref(name, new)

    def cas_refs(self, updates: Sequence[Tuple[str, Optional[str], str]]
                 ) -> None:
        # validate every expectation against the *tiered* view, apply every
        # write locally — all inside the local store's cross-process guard,
        # so the batch is all-or-nothing exactly like ObjectStore.cas_refs
        with self.local.ref_guard():
            for name, expected, _new in updates:
                try:
                    current: Optional[str] = self.get_ref(name)
                except RefNotFound:
                    current = None
                if current != expected:
                    raise RefConflict(
                        f"ref {name}: expected {expected!r}, found "
                        f"{current!r} (no ref in this batch was updated)")
            for name, _expected, new in updates:
                self.local.set_ref(name, new)

    def delete_ref(self, name: str) -> None:
        self.local.delete_ref(name)

    def iter_refs(self, prefix: str = "") -> Iterator[str]:
        names = set(self.local.iter_refs(prefix))
        try:
            names.update(self.remote.iter_refs(prefix))
        except ReproError:  # unreachable remote: degrade to the local tier
            pass
        yield from sorted(names)

    def list_refs(self, prefix: str = "", *,
                  page_token: Optional[str] = None, limit: int = 1000
                  ) -> Tuple[List[Tuple[str, str]], Optional[str]]:
        limit = max(1, limit)
        page: List[Tuple[str, str]] = []
        last: Optional[str] = None
        for name in self.iter_refs(prefix):
            if page_token is not None and name <= page_token:
                continue
            try:
                page.append((name, self.get_ref(name)))
            except RefNotFound:
                continue
            last = name
            if len(page) >= limit:
                return page, last
        return page, None


# ----------------------------------------------------------------- connectors
def connect(url_or_path: str, *, retries: int = 2,
            allow_delete: bool = False) -> StoreBackend:
    """Open a remote store from a URL or a filesystem path:

    * ``http(s)://host:port`` — msgpack wire protocol (``repro serve``);
    * ``s3://host:port/bucket`` — S3-compatible REST dialect
      (:class:`~repro.core.s3.S3Backend`; ``repro serve --s3`` or any
      server speaking the dialect);
    * a path — served through an in-process loopback, so every access
      still exercises the full wire contract.

    ``allow_delete`` opens the remote-side GC sweep path
    (``repro gc --remote``); S3 backends are direct object-store clients,
    so the flag only gates the msgpack protocol's ``delete_object`` op."""
    if url_or_path.startswith("s3://"):
        from .s3 import S3Backend

        return S3Backend.from_url(url_or_path, retries=retries)
    if url_or_path.startswith(("http://", "https://")):
        return RemoteStore(HTTPTransport(url_or_path), retries=retries,
                           allow_delete=allow_delete)
    path = url_or_path[len("file://"):] if url_or_path.startswith("file://") \
        else url_or_path
    return RemoteStore(LoopbackTransport(RemoteServer(ObjectStore(path))),
                       retries=retries, allow_delete=allow_delete)
