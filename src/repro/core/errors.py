"""Exception hierarchy for the repro core (catalog / store / pipeline)."""


class ReproError(Exception):
    """Base class for all repro errors."""


class ObjectNotFound(ReproError):
    """A content-addressed object is missing from the store."""


class RefNotFound(ReproError):
    """A branch/tag ref does not exist."""


class RefConflict(ReproError):
    """Compare-and-set on a ref failed (concurrent writer)."""


class TableNotFound(ReproError):
    """A table is not present in the commit being read."""


class SchemaError(ReproError):
    """Schema mismatch between producer and consumer."""


class MergeConflict(ReproError):
    """Three-way merge found tables changed on both sides."""

    def __init__(self, tables):
        self.tables = list(tables)
        super().__init__(f"merge conflict on tables: {self.tables}")


class TransactionConflict(MergeConflict):
    """An optimistic table-level transaction could not land.

    Raised by ``Catalog.commit``/``Catalog.merge`` when, after a ref-level
    CAS miss, the rebase check finds that a table in the transaction's
    declared read/write set changed snapshot since the transaction's base
    (``tables`` names them), or when the bounded rebase loop ran out of
    attempts under sustained contention (``tables`` is then empty and
    ``exhausted`` is True).  A plain concurrent commit to *disjoint*
    tables never raises this — the transaction rebases and retries
    internally.  Subclasses :class:`MergeConflict` so existing
    conflict-handling callers keep working."""

    def __init__(self, branch, tables, *, attempts, base=None,
                 exhausted=False, pinned=False):
        self.branch = branch
        self.attempts = attempts
        self.base = base
        self.exhausted = exhausted
        #: True when the transaction was pinned to an exact base commit
        #: (``expected_head=``) — movement alone is a conflict, no rebase
        self.pinned = pinned
        super().__init__(tables)
        what = ("transaction pinned to stale base" if pinned
                else "rebase attempts exhausted" if exhausted
                else f"concurrent writes to tables {self.tables}")
        self.args = (f"transaction on {branch!r} conflicted after "
                     f"{attempts} attempt(s): {what}",)


class PermissionDenied(ReproError):
    """Namespace policy rejected a write."""


class CycleError(ReproError):
    """The pipeline DAG has a cycle."""


class NodeExecutionError(ReproError):
    """A DAG node failed during execution.

    Carries the failing node's identity plus the :class:`NodeStat` of every
    node that completed before the failure was observed — the executor used
    to throw both away, leaving only the bare exception of the worker
    thread.  ``attempts`` counts lease claims on the node: 1 for a plain
    in-process failure, >1 when the distributed coordinator re-leased the
    node after worker crashes and finally gave up (the poison pill)."""

    def __init__(self, node, message, *, node_stats=None, attempts=1):
        self.node = node
        self.node_stats = dict(node_stats or {})
        self.attempts = attempts
        super().__init__(
            f"node {node!r} failed after {attempts} attempt(s): {message}")


class RunAborted(ReproError):
    """Internal: a sibling node's failure aborted this in-flight node
    before it wrote any snapshot or cache entry.  Never escapes
    ``execute`` — the coordinator swallows it while draining."""


class ExpectationFailed(ReproError):
    """A write-audit-publish expectation failed."""


class ContractViolation(ExpectationFailed):
    """A data contract attached to a table in the catalog rejected the new
    snapshot.  Raised at the ref update itself (``commit``/``merge``/
    ``publish`` all funnel through it), so a writer cannot land violating
    data by skipping the write-audit-publish ceremony — the catalog, not
    caller cooperation, enforces the contract."""

    def __init__(self, branch, table, failures):
        self.branch = branch
        self.table = table
        #: rule name -> error string (or "failed" for a clean False)
        self.failures = dict(failures)
        super().__init__(
            f"contract on table {table!r} rejected commit to {branch!r}: "
            f"{self.failures}")


class CodeDrift(ReproError):
    """Replay requested but the registered node code differs from the run manifest."""


class RunNotFound(ReproError):
    """Unknown run id in the ledger."""


class CodecUnavailable(ObjectNotFound):
    """A blob is compressed with a codec this host cannot decode (e.g. a
    zstd payload on a host without the zstandard package).  Subclasses
    :class:`ObjectNotFound` so plain reads keep their existing contract;
    the transfer engine catches it specifically to fall back from encoded
    wire frames to raw blob transfer."""


class RemoteError(ReproError):
    """A remote store request failed (transport fault, protocol error)."""


class AmbiguousRefUpdate(RemoteError):
    """A transport fault interrupted a non-idempotent ref update
    (``cas_ref``/``cas_refs``) after the request may already have been
    delivered: the remote ref state is UNKNOWN — the update may or may not
    have been applied.  Distinct from a clean :class:`RemoteError` failure
    so callers (push/pull) can resolve the ambiguity by re-reading the
    remote refs instead of reporting a failure that silently succeeded."""


class SyncError(ReproError):
    """push/pull/clone could not complete (diverged refs, missing remote)."""
