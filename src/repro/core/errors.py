"""Exception hierarchy for the repro core (catalog / store / pipeline)."""


class ReproError(Exception):
    """Base class for all repro errors."""


class ObjectNotFound(ReproError):
    """A content-addressed object is missing from the store."""


class RefNotFound(ReproError):
    """A branch/tag ref does not exist."""


class RefConflict(ReproError):
    """Compare-and-set on a ref failed (concurrent writer)."""


class TableNotFound(ReproError):
    """A table is not present in the commit being read."""


class SchemaError(ReproError):
    """Schema mismatch between producer and consumer."""


class MergeConflict(ReproError):
    """Three-way merge found tables changed on both sides."""

    def __init__(self, tables):
        self.tables = list(tables)
        super().__init__(f"merge conflict on tables: {self.tables}")


class PermissionDenied(ReproError):
    """Namespace policy rejected a write."""


class CycleError(ReproError):
    """The pipeline DAG has a cycle."""


class ExpectationFailed(ReproError):
    """A write-audit-publish expectation failed."""


class CodeDrift(ReproError):
    """Replay requested but the registered node code differs from the run manifest."""


class RunNotFound(ReproError):
    """Unknown run id in the ledger."""


class RemoteError(ReproError):
    """A remote store request failed (transport fault, protocol error)."""


class SyncError(ReproError):
    """push/pull/clone could not complete (diverged refs, missing remote)."""
