"""Mark-and-sweep garbage collection for the tensor lake.

Immutable content-addressed objects accumulate forever (every commit,
snapshot, tensorfile and run manifest).  Real lakehouses expire unreachable
data; here: roots = every branch/tag head (including remote-tracking refs
``remote/<name>/branch=<b>`` left by push/pull) + every run-ledger link;
mark = walk commits → snapshots → manifest files (+ run manifests → result
commits); sweep = delete unmarked objects.  On a ``TieredStore`` the sweep
only touches the local tier — the shared remote is never collected from a
client.

Safe against concurrent writers — three mechanisms, layered
(docs/remote_store.md, "Concurrent-safe remote GC"):

* **generation token** (:data:`~repro.core.store.GC_GENERATION_REF`): a
  sweep bumps it *before* marking; every push/pull validates the token it
  captured at transfer start inside its final ``cas_refs`` batch, so a
  sync that raced a sweep fails its ref update cleanly and re-uploads
  instead of publishing refs to deleted blobs;
* **upload-age grace window** (``prune_age``): the sweep never deletes an
  object younger than ``prune_age`` seconds (fs: stat mtime, S3:
  ``Last-Modified``, wire: the ``stat_object`` op) — uploads made *during*
  the mark/sweep itself, which no token can fence, are protected by age;
* **server-side mark** (``gc_mark``/``gc_sweep`` wire ops): against a
  msgpack remote the whole mark phase runs on the server over its own
  store — no per-object wire reads — and the sweep's age checks are local
  stats.  A server predating the ops degrades to a client-side mark with a
  loud warning (never a crash); a direct S3 remote always marks
  client-side (the bucket runs no code) but keeps the grace window via
  ``Last-Modified``.

Remote-side GC (``repro gc --remote NAME``) runs mark-and-sweep *against
the remote itself*: ``collect`` takes any ``StoreBackend``, so handed an
opted-in :class:`~repro.core.remote.RemoteStore` (``allow_delete=True``)
or an :class:`~repro.core.s3.S3Backend` it marks from the remote's OWN
refs and sweeps via the remote's ``delete_object`` — local state is never
consulted, so a stale or divergent local mirror can neither protect nor
doom a remote object.

Because branches are the only mutable state, deleting a branch is what makes
its unique history collectable — a paper-consistent retention story
(nothing reachable from a ref is ever collected, so replayability of
*recorded* runs survives GC as long as their ledger links remain).
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Optional, Set, Tuple

import msgpack

from .catalog import (_BRANCH_PREFIX, _TAG_PREFIX, REMOTE_REF_PREFIX,
                      Commit)
from .errors import ObjectNotFound, RemoteError
from .exec.lease import EXEC_REF_PREFIX, lease_ref_digests
from .ledger import _RUNS_HEAD
from .runcache import CACHE_REF_PREFIX
from .store import ObjectStore, StoreBackend, bump_generation

#: default upload-age grace window (seconds) for the CLI sweep — the
#: ``git gc --prune=<age>`` analogue.  Library callers of :func:`collect`
#: default to 0.0 (sweep everything unreachable) for compatibility.
DEFAULT_PRUNE_AGE = 3600.0


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass
class GCReport:
    live: int
    swept: int
    bytes_freed: int
    #: unreachable objects left alone because they were younger than
    #: ``prune_age`` (an in-flight push's not-yet-referenced uploads)
    skipped_young: int = 0
    #: generation token the sweep ran under (None: dry run / no bump)
    generation: Optional[str] = None
    #: how the mark phase ran: ``local`` (same-process filesystem store),
    #: ``server`` (gc_mark/gc_sweep wire ops), ``client`` (direct remote
    #: backend with no server to run code on, e.g. S3 — per-object
    #: reads by design), ``client-fallback`` (msgpack server that
    #: predates the ops — per-object wire reads, loudly warned)
    mode: str = "local"


def _is_commit_root(ref: str) -> bool:
    """Refs whose target commit roots a live closure: local branches/tags
    (``branch=main``, ``tag=v1.0``) and remote-tracking refs left by
    push/pull (``remote/<name>/branch=<b>``, ``remote/<name>/tag=<t>``).

    Matched on the prefix *after* the remote namespace, never on the ref
    path's basename: ref names may themselves contain ``/`` (a tag like
    ``release/v1`` shards into subdirectories), and basename matching
    silently dropped those from the root set — a tag synced from a remote
    stopped protecting its closure the moment the local branch pointing at
    the same history was deleted (regression test in tests/test_gc.py)."""
    if ref.startswith((_BRANCH_PREFIX, _TAG_PREFIX)):
        return True
    if ref.startswith(REMOTE_REF_PREFIX):
        rest = ref[len(REMOTE_REF_PREFIX):].split("/", 1)
        return len(rest) == 2 and rest[1].startswith((_BRANCH_PREFIX,
                                                      _TAG_PREFIX))
    return False


def _mark_commit(store: StoreBackend, digest: str, live: Set[str]):
    stack = [digest]
    while stack:
        d = stack.pop()
        if d in live or not store.has(d):
            continue
        live.add(d)
        commit = Commit.from_obj(_unpack(store.get(d)))
        stack.extend(commit.parents)
        for snap_digest in commit.tables.values():
            _mark_snapshot(store, snap_digest, live)


def _mark_snapshot(store: StoreBackend, digest: str, live: Set[str]):
    while digest is not None and digest not in live:
        if not store.has(digest):
            return
        live.add(digest)
        snap = _unpack(store.get(digest))
        mlist_digest = snap.get("manifest_list")
        if mlist_digest is not None:
            # v1 hierarchy: snapshot -> manifest-list -> manifests -> files.
            # Manifests dedup across snapshots (an append reuses its
            # parent's verbatim), so the `in live` check skips whole
            # subtrees already marked via another snapshot.
            if mlist_digest not in live and store.has(mlist_digest):
                live.add(mlist_digest)
                mlist = _unpack(store.get(mlist_digest))
                for row in mlist.get("manifests", []):
                    m_digest = row[0]
                    if m_digest in live or not store.has(m_digest):
                        continue
                    live.add(m_digest)
                    manifest = _unpack(store.get(m_digest))
                    for entry in manifest.get("entries", []):
                        live.add(entry[0])  # tensorfile digest
        else:
            for entry in snap.get("manifest", []):  # legacy v0: inline
                live.add(entry[0])  # tensorfile digest
        digest = snap.get("parent")


def mark_live(store: StoreBackend, *, drop_cache: bool = False,
              dry_run: bool = False) -> Set[str]:
    """The mark phase: every digest reachable from ``store``'s own refs.

    Run-cache entries are GC roots (their entry blobs + output snapshots
    stay live) unless ``drop_cache`` — then the cache refs are deleted
    first and any snapshot only the cache referenced becomes sweepable (a
    later warm run simply degrades to a miss).  Exposed standalone so the
    ``gc_mark`` wire op can run this server-side over the server's local
    store — no per-object wire reads."""
    if drop_cache and not dry_run:
        for ref in list(store.iter_refs(CACHE_REF_PREFIX)):
            store.delete_ref(ref)
    live: Set[str] = set()
    for ref in store.iter_refs():
        head = store.get_ref(ref)
        # Commit roots: local branches/tags AND remote-tracking refs
        # (``remote/<name>/branch=<b>``, ``remote/<name>/tag=<t>``).
        # History reachable only through a remote-tracking ref — e.g. a
        # pulled branch or synced tag whose local ref was deleted — must
        # survive, or replaying it after gc would break.
        if _is_commit_root(ref):
            _mark_commit(store, head, live)
        elif ref.startswith(CACHE_REF_PREFIX):  # cache entry -> snapshot
            if drop_cache:  # dry_run: pretend the cache is gone
                continue
            if store.has(head):
                live.add(head)
                entry = _unpack(store.get(head))
                snap = entry.get("snapshot")
                if snap:
                    _mark_snapshot(store, snap, live)
        elif ref.startswith(EXEC_REF_PREFIX):
            # executor state: run-record / task / result / error blobs are
            # live while the lease refs exist (an in-flight run must not
            # have its coordination blobs swept from under it); a done
            # node's result additionally pins its output snapshot until
            # the coordinator commits and drops the lease refs
            for digest in lease_ref_digests(ref, head):
                if not store.has(digest):
                    continue
                live.add(digest)
                payload = _unpack(store.get(digest))
                if isinstance(payload, dict):
                    snaps = [payload.get("snapshot")]
                    for stat in (payload.get("nodes") or {}).values():
                        if isinstance(stat, dict):
                            snaps.append(stat.get("snapshot"))
                    for snap in snaps:
                        if isinstance(snap, str):
                            _mark_snapshot(store, snap, live)
        elif ref == _RUNS_HEAD:  # run-ledger chain: links + manifests
            cur = head
            while cur is not None and store.has(cur):
                if cur in live:
                    break
                live.add(cur)
                link = _unpack(store.get(cur))
                manifest_digest = link.get("manifest")
                if manifest_digest and store.has(manifest_digest):
                    live.add(manifest_digest)
                    manifest = _unpack(store.get(manifest_digest))
                    for c in (manifest.get("data_commit"),
                              manifest.get("result_commit")):
                        if c:
                            _mark_commit(store, c, live)
                    for snap in manifest.get("outputs", {}).values():
                        _mark_snapshot(store, snap, live)
                cur = link.get("prev")
    return live


def sweep(store: StoreBackend, live: Set[str], *, prune_age: float = 0.0,
          dry_run: bool = False,
          now: Optional[float] = None) -> Tuple[int, int, int]:
    """The sweep phase: delete unmarked objects OLDER than ``prune_age``
    seconds.  Returns ``(swept, bytes_freed, skipped_young)``.

    The age check is the grace window: objects an in-flight push uploaded
    but has not referenced yet (refs move last) look unreachable to the
    mark, but they are by construction *young* — skipping anything newer
    than ``prune_age`` makes the sweep safe to run concurrently with
    pushes whose uploads no generation token can fence (they started after
    the bump).  When ages cannot be read at all (a backend without
    ``stat``, or a server predating the ``stat_object`` op), everything
    unreachable is treated as OLD — the pre-grace-window behavior — with
    a loud warning about the downgrade.  Age and size come from one
    ``stat`` per candidate (one wire round-trip, not two)."""
    swept = 0
    freed = 0
    skipped_young = 0
    now = time.time() if now is None else now
    use_ages = prune_age > 0
    # capability probe up front — deliberately NOT a per-object
    # AttributeError catch, which would let a bug inside a present stat()
    # silently disable the window and sweep in-flight uploads
    stat = getattr(store, "stat", None)
    if use_ages and stat is None:
        use_ages = False
        warnings.warn(
            "gc: backend has no stat()/object ages; the --prune-age "
            "grace window is DISABLED for this sweep — do not run it "
            "concurrently with pushes", RuntimeWarning, stacklevel=2)
    for digest in list(store.iter_objects()):
        if digest in live:
            continue
        size = None
        if use_ages:
            try:
                size, mtime = stat(digest)
            except ObjectNotFound:
                continue  # concurrently deleted — nothing left to sweep
            except RemoteError as e:
                if "unknown op" not in str(e):
                    raise  # transient wire fault — abort, never mis-age
                # server predates stat_object: no age data exists, so the
                # window cannot be honored — degrade (once, loudly) to
                # the legacy sweep-everything-unreachable behavior
                use_ages = False
                size = None
                warnings.warn(
                    f"gc: backend cannot report object ages ({e!r}); "
                    "the --prune-age grace window is DISABLED for this "
                    "sweep — do not run it concurrently with pushes",
                    RuntimeWarning, stacklevel=2)
            else:
                if now - mtime < prune_age:
                    skipped_young += 1
                    continue
        if size is None:
            try:
                size = store.size(digest)
            except ObjectNotFound:
                continue  # concurrently deleted
        freed += size
        if not dry_run:
            store.delete_object(digest)
        swept += 1
    return swept, freed, skipped_young


def _is_unknown_op(e: RemoteError) -> bool:
    return "bad_request" in str(e) and "unknown op" in str(e)


def _collect_via_server(store, *, dry_run: bool, drop_cache: bool,
                        prune_age: float) -> GCReport:
    """Mark + sweep through the ``gc_mark``/``gc_sweep`` wire ops: the
    server walks its own refs and stats its own files — the only wire
    traffic is two requests.  Raises :class:`RemoteError` with the
    server's "unknown op" reply when it predates the ops (the caller
    falls back client-side)."""
    generation, live_count = store.gc_mark(drop_cache=drop_cache,
                                           dry_run=dry_run)
    swept, freed, young = store.gc_sweep(generation, prune_age=prune_age,
                                         dry_run=dry_run)
    return GCReport(live=live_count, swept=swept, bytes_freed=freed,
                    skipped_young=young,
                    generation=None if dry_run else generation,
                    mode="server")


def collect(store: StoreBackend, *, dry_run: bool = False,
            drop_cache: bool = False,
            prune_age: float = 0.0) -> GCReport:
    """Mark from all refs; sweep unreachable objects older than
    ``prune_age`` seconds (0 = sweep everything unreachable; the CLI
    defaults to :data:`DEFAULT_PRUNE_AGE`).

    A real (non-dry) sweep first bumps the GC generation token
    (:func:`~repro.core.store.bump_generation`) so concurrent pushes fail
    their ref update cleanly instead of referencing deleted blobs.  Against
    a :class:`~repro.core.remote.RemoteStore` whose server speaks
    ``gc_mark``/``gc_sweep``, the whole mark runs server-side; a server
    that predates the ops falls back to the client-side mark with a loud
    :class:`RuntimeWarning` (per-object wire reads — slow, and the grace
    window then depends on the ``stat_object`` op)."""
    # On a TieredStore, collect strictly the local tier: marking through the
    # tiered view would fault every remote blob over the network into the
    # local store (read-through write-back), turning gc into a full mirror.
    # Local refs (incl. remote-tracking refs, which live locally) are the
    # roots; mark walks simply stop at objects that only exist remotely.
    store = getattr(store, "local", store)
    if getattr(store, "gc_mark", None) is not None:
        try:
            return _collect_via_server(store, dry_run=dry_run,
                                       drop_cache=drop_cache,
                                       prune_age=prune_age)
        except RemoteError as e:
            if not _is_unknown_op(e):
                raise
            warnings.warn(
                "gc --remote: this server predates the gc_mark/gc_sweep "
                "wire ops — falling back to a CLIENT-SIDE mark (one wire "
                "read per commit/snapshot; slow on large remotes, and the "
                "grace window depends on the stat_object op). Upgrade the "
                "server.", RuntimeWarning, stacklevel=2)
            mode = "client-fallback"
    else:
        mode = "local" if isinstance(store, ObjectStore) else "client"
    generation: Optional[str] = None
    if not dry_run:
        # bump BEFORE marking: a sync that captured the pre-bump token —
        # the only sync whose uploads could predate this mark — can no
        # longer publish refs without a clean conflict + retry
        generation = bump_generation(store)
    live = mark_live(store, drop_cache=drop_cache, dry_run=dry_run)
    swept, freed, young = sweep(store, live, prune_age=prune_age,
                                dry_run=dry_run)
    return GCReport(live=len(live), swept=swept, bytes_freed=freed,
                    skipped_young=young, generation=generation, mode=mode)
