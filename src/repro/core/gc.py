"""Mark-and-sweep garbage collection for the tensor lake.

Immutable content-addressed objects accumulate forever (every commit,
snapshot, tensorfile and run manifest).  Real lakehouses expire unreachable
data; here: roots = every branch/tag head (including remote-tracking refs
``remote/<name>/branch=<b>`` left by push/pull) + every run-ledger link;
mark = walk commits → snapshots → manifest files (+ run manifests → result
commits); sweep = delete unmarked objects.  On a ``TieredStore`` the sweep
only touches the local tier — the shared remote is never collected from a
client.

Remote-side GC (``repro gc --remote NAME``) runs the same mark-and-sweep
*against the remote itself*: ``collect`` takes any ``StoreBackend``, so
handed an opted-in :class:`~repro.core.remote.RemoteStore`
(``allow_delete=True``) or an :class:`~repro.core.s3.S3Backend` it marks
from the remote's OWN refs and sweeps via the remote's ``delete_object``
— local state is never consulted, so a stale or divergent local mirror
can neither protect nor doom a remote object.  Run it in a quiet window:
objects an in-flight push has uploaded but not yet referenced (refs move
last) look unreachable to a racing sweep — there is no upload-age grace
period yet (see docs/remote_store.md).

Because branches are the only mutable state, deleting a branch is what makes
its unique history collectable — a paper-consistent retention story
(nothing reachable from a ref is ever collected, so replayability of
*recorded* runs survives GC as long as their ledger links remain).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Set

import msgpack

from .catalog import (_BRANCH_PREFIX, _TAG_PREFIX, REMOTE_REF_PREFIX,
                      Catalog, Commit)
from .ledger import _RUNS_HEAD
from .runcache import CACHE_REF_PREFIX
from .store import ObjectStore, StoreBackend


def _unpack(blob: bytes):
    return msgpack.unpackb(blob, raw=False)


@dataclass
class GCReport:
    live: int
    swept: int
    bytes_freed: int


def _is_commit_root(ref: str) -> bool:
    """Refs whose target commit roots a live closure: local branches/tags
    (``branch=main``, ``tag=v1.0``) and remote-tracking refs left by
    push/pull (``remote/<name>/branch=<b>``, ``remote/<name>/tag=<t>``).

    Matched on the prefix *after* the remote namespace, never on the ref
    path's basename: ref names may themselves contain ``/`` (a tag like
    ``release/v1`` shards into subdirectories), and basename matching
    silently dropped those from the root set — a tag synced from a remote
    stopped protecting its closure the moment the local branch pointing at
    the same history was deleted (regression test in tests/test_gc.py)."""
    if ref.startswith((_BRANCH_PREFIX, _TAG_PREFIX)):
        return True
    if ref.startswith(REMOTE_REF_PREFIX):
        rest = ref[len(REMOTE_REF_PREFIX):].split("/", 1)
        return len(rest) == 2 and rest[1].startswith((_BRANCH_PREFIX,
                                                      _TAG_PREFIX))
    return False


def _mark_commit(store: StoreBackend, digest: str, live: Set[str]):
    stack = [digest]
    while stack:
        d = stack.pop()
        if d in live or not store.has(d):
            continue
        live.add(d)
        commit = Commit.from_obj(_unpack(store.get(d)))
        stack.extend(commit.parents)
        for snap_digest in commit.tables.values():
            _mark_snapshot(store, snap_digest, live)


def _mark_snapshot(store: StoreBackend, digest: str, live: Set[str]):
    while digest is not None and digest not in live:
        if not store.has(digest):
            return
        live.add(digest)
        snap = _unpack(store.get(digest))
        for entry in snap.get("manifest", []):
            live.add(entry[0])  # tensorfile digest
        digest = snap.get("parent")


def collect(store: StoreBackend, *, dry_run: bool = False,
            drop_cache: bool = False) -> GCReport:
    """Mark from all refs; sweep unreachable objects.

    Run-cache entries are GC roots (their entry blobs + output snapshots stay
    live) unless ``drop_cache`` — then the cache refs are deleted first and
    any snapshot only the cache referenced is swept (a later warm run simply
    degrades to a miss)."""
    # On a TieredStore, collect strictly the local tier: marking through the
    # tiered view would fault every remote blob over the network into the
    # local store (read-through write-back), turning gc into a full mirror.
    # Local refs (incl. remote-tracking refs, which live locally) are the
    # roots; mark walks simply stop at objects that only exist remotely.
    store = getattr(store, "local", store)
    if drop_cache and not dry_run:
        for ref in list(store.iter_refs(CACHE_REF_PREFIX)):
            store.delete_ref(ref)
    live: Set[str] = set()
    for ref in store.iter_refs():
        head = store.get_ref(ref)
        # Commit roots: local branches/tags AND remote-tracking refs
        # (``remote/<name>/branch=<b>``, ``remote/<name>/tag=<t>``).
        # History reachable only through a remote-tracking ref — e.g. a
        # pulled branch or synced tag whose local ref was deleted — must
        # survive, or replaying it after gc would break.
        if _is_commit_root(ref):
            _mark_commit(store, head, live)
        elif ref.startswith(CACHE_REF_PREFIX):  # cache entry -> snapshot
            if drop_cache:  # dry_run: pretend the cache is gone
                continue
            if store.has(head):
                live.add(head)
                entry = _unpack(store.get(head))
                snap = entry.get("snapshot")
                if snap:
                    _mark_snapshot(store, snap, live)
        elif ref == _RUNS_HEAD:  # run-ledger chain: links + manifests
            cur = head
            while cur is not None and store.has(cur):
                if cur in live:
                    break
                live.add(cur)
                link = _unpack(store.get(cur))
                manifest_digest = link.get("manifest")
                if manifest_digest and store.has(manifest_digest):
                    live.add(manifest_digest)
                    manifest = _unpack(store.get(manifest_digest))
                    for c in (manifest.get("data_commit"),
                              manifest.get("result_commit")):
                        if c:
                            _mark_commit(store, c, live)
                    for snap in manifest.get("outputs", {}).values():
                        _mark_snapshot(store, snap, live)
                cur = link.get("prev")

    swept = 0
    freed = 0
    for digest in list(store.iter_objects()):
        if digest in live:
            continue
        freed += store.size(digest)
        if not dry_run:
            store.delete_object(digest)
        swept += 1
    return GCReport(live=len(live), swept=swept, bytes_freed=freed)
