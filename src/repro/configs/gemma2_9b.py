"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab_size=256000, rope_theta=10_000.0,
    sliding_window=4096, global_every=2,       # local, global, local, ...
    attn_softcap=50.0, final_softcap=30.0,
    mlp_act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, sliding_window=8, global_every=2,
    attn_softcap=50.0, final_softcap=30.0, mlp_act="gelu",
    tie_embeddings=True, param_dtype="float32", compute_dtype="float32",
)
