"""minicpm-2b — llama-like dense, trained with the WSD schedule
[arXiv:2404.06395].  The WSD (warmup-stable-decay) schedule is implemented in
``repro.optim.schedules`` and selected by this arch's training recipe."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_head=64,
    d_ff=5760, vocab_size=122753, rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=6, d_head=8,
    d_ff=96, vocab_size=211,  # odd vocab on purpose (122753 is odd too)
    tie_embeddings=True, param_dtype="float32", compute_dtype="float32",
)

TRAIN_SCHEDULE = "wsd"  # the arch's published training recipe
