"""musicgen-large — decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284].  The EnCodec frontend is a STUB: ``input_specs()``
supplies precomputed conditioning frame embeddings that occupy the first
``n_frontend_embeds`` positions (loss-masked)."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-large", family="dense",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=2048, rope_theta=10_000.0,
    mlp_act="gelu",
    frontend="audio", n_frontend_embeds=64,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=128, mlp_act="gelu",
    frontend="audio", n_frontend_embeds=4,
    param_dtype="float32", compute_dtype="float32",
)
