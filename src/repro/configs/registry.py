"""Config registry: one module per assigned arch, resolved by id."""

from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig

ARCH_IDS = [
    "yi-34b",
    "gemma2-9b",
    "minicpm-2b",
    "qwen2.5-14b",
    "mamba2-370m",
    "hymba-1.5b",
    "qwen2-moe-a2.7b",
    "qwen3-moe-235b-a22b",
    "musicgen-large",
    "internvl2-76b",
    "paper-demo",  # the paper's own pipeline demo model (~100M)
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def full_config(arch: str) -> ModelConfig:
    return _load(arch).FULL


def smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE


def list_archs() -> List[str]:
    return list(ARCH_IDS)
