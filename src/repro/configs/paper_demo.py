"""paper-demo — the ~100M-parameter model used by the paper-style end-to-end
example (train a small LM through a replayable catalog-backed pipeline,
``examples/train_lm.py``)."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="paper-demo", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
    d_ff=2048, vocab_size=32768, tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32",
)

SMOKE = ModelConfig(
    name="paper-demo-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=512, tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32",
)
