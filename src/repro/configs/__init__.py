"""Assigned architecture configs (``--arch <id>``) + reduced smoke variants.

Every config is the EXACT published configuration from the assignment table;
``smoke_config(id)`` returns a reduced same-family variant for CPU tests.
"""

from .registry import (ARCH_IDS, full_config, list_archs, smoke_config)

__all__ = ["ARCH_IDS", "full_config", "smoke_config", "list_archs"]
