"""qwen3-moe-235b-a22b — 128 routed experts top-8, no shared experts
[hf:Qwen/Qwen3 family]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    vocab_size=151936, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, expert_d_ff=1536, n_shared_experts=0,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    vocab_size=256,
    n_experts=16, top_k=8, expert_d_ff=16, n_shared_experts=0,
    param_dtype="float32", compute_dtype="float32",
)
