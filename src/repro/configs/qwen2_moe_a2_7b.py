"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    vocab_size=151936, rope_theta=1_000_000.0, qkv_bias=True,
    n_experts=60, top_k=4, expert_d_ff=1408, n_shared_experts=4,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    vocab_size=256, qkv_bias=True,
    n_experts=8, top_k=4, expert_d_ff=32, n_shared_experts=2,
    param_dtype="float32", compute_dtype="float32",
)
