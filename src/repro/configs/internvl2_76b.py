"""internvl2-76b — InternViT + InternLM2 VLM [arXiv:2404.16821].
The backbone below is the InternLM2-76B language tower; the InternViT
frontend is a STUB: ``input_specs()`` supplies precomputed patch embeddings
(256 per image) that pass through a trained connector and occupy the first
``n_frontend_embeds`` positions (loss-masked)."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256, rope_theta=1_000_000.0,
    frontend="vision", n_frontend_embeds=256,
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab_size=256,
    frontend="vision", n_frontend_embeds=8,
    param_dtype="float32", compute_dtype="float32",
)
