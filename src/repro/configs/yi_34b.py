"""yi-34b — dense llama-arch GQA [arXiv:2403.04652]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab_size=64000, rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab_size=256, rope_theta=5_000_000.0,
    param_dtype="float32", compute_dtype="float32",
)
