"""hymba-1.5b — hybrid: parallel attention + mamba heads in every layer
[arXiv:2411.13676].  Sliding-window attention everywhere except 3 full-
attention layers (first / middle / last), as in the paper; meta-tokens are
not modelled (noted in DESIGN.md).  Sub-quadratic ⇒ runs ``long_500k``."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001, rope_theta=10_000.0,
    sliding_window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=257, sliding_window=8, global_layers=(0, 2),
    ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    param_dtype="float32", compute_dtype="float32",
)
