"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=13824, vocab_size=152064, rope_theta=1_000_000.0,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab_size=256, qkv_bias=True,
    param_dtype="float32", compute_dtype="float32",
)
