"""mamba2-370m — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].  d_inner = 2*1024 = 2048, 32 ssd heads of dim 64."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab_size=50280,
    d_ff=0,  # attention-free, MLP-free: the mixer IS the layer
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, vocab_size=256, d_ff=0,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    tie_embeddings=True, param_dtype="float32", compute_dtype="float32",
)
