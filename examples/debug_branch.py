"""Fig. 4 as a CLI session: the bauplan-style commands of Listing 3 driven
through the ``repro`` CLI (launch/repro_cli.py).

  bauplan checkout richard.debug_branch   →  repro branch richard.debug
  bauplan run --id=1441804                →  repro run --id <run_id>
  bauplan query "SELECT COUNT(*) ..."     →  repro query "SELECT count(*) ..."

Run:  PYTHONPATH=src python examples/debug_branch.py
"""

import tempfile

import numpy as np

from repro.core import Lake
from repro.data import build_data_pipeline, seed_corpus
from repro.launch.repro_cli import main as cli


def main():
    tmp = tempfile.mkdtemp(prefix="repro_cli_lake_")
    lake = Lake(tmp)
    lake.catalog.create_branch("data.main", "main", author="data")
    seed_corpus(lake, "data.main", n_docs=64, seed=3, vocab_size=512,
                mean_len=100, author="data")
    print(f"$ # lake at {tmp}")

    def sh(*args):
        print(f"$ repro {' '.join(args)}")
        cli(["--lake", tmp, *args])

    # nightly production run (cron in the paper)
    sh("run", "--pipeline", "data", "--seq-len", "128",
       "--branch", "data.main", "--author", "data")
    run_id = lake.ledger.runs()[0]

    # Listing 3, line 1: create the debug branch
    sh("branch", "richard.debug", "--from", "data.main")
    # Listing 3, line 2: replay last night's run by id
    sh("run", "--id", run_id, "--pipeline", "data", "--seq-len", "128",
       "--branch", "richard.debug2", "--author", "richard")
    # Listing 3, line 3: query the reproduced artifact
    sh("query", "SELECT count(*) FROM packed", "--ref", "richard.debug2")
    sh("query", "SELECT count(*) FROM data_stats", "--ref", "richard.debug2")

    # catalog inspection
    sh("branches")
    sh("log", "data.main")
    sh("runs")


if __name__ == "__main__":
    main()
