"""Serving example: batched requests against a model whose weights are
pinned to an immutable catalog commit (Fig. 3 read path, applied to
inference).  Trains a few steps first so there is a checkpoint to serve.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import tempfile

import numpy as np

from repro.checkpoint import latest_checkpoint
from repro.configs import smoke_config
from repro.core import Lake
from repro.data import build_data_pipeline, seed_corpus
from repro.runtime import Trainer, TrainerConfig
from repro.serving import BatchedServer, ServeEngine


def main():
    cfg = smoke_config("paper-demo")
    tmp = tempfile.mkdtemp(prefix="repro_serve_")
    lake = Lake(tmp)

    # quick training run to produce a served checkpoint
    lake.catalog.create_branch("data.main", "main", author="data")
    seed_corpus(lake, "data.main", n_docs=128, seed=1,
                vocab_size=cfg.vocab_size, mean_len=120, author="data")
    lake.run(build_data_pipeline(64), branch="data.main", author="data")
    tcfg = TrainerConfig(arch=cfg.name, seq_len=64, global_batch=8,
                         n_steps=20, ckpt_every=10, author="trainer",
                         schedule="constant",
                         schedule_kw={"peak_lr": 1e-3})
    trainer = Trainer(lake, cfg, tcfg, data_branch="data.main",
                      run_name="serve-src")
    trainer.run()
    commit = latest_checkpoint(lake, trainer.run_branch)
    print(f"serving from checkpoint commit {commit[:12]}")

    # the engine pins its weights to that immutable commit
    engine = ServeEngine.from_catalog(lake, commit, cfg, max_len=96,
                                      batch_size=4)
    server = BatchedServer(engine)
    rng = np.random.default_rng(0)
    for rid in range(10):
        plen = int(rng.integers(4, 40))
        server.submit(rid, rng.integers(
            3, cfg.vocab_size, plen).astype(np.int32), n_tokens=12)
    total = 0
    while server.pending:   # queued AND in-flight — batching is continuous
        total += server.step()
    print(f"served {total} requests; every response cites model_commit="
          f"{engine.model_commit[:12]}")
    for rid in (0, 1):
        r = server.completed[rid]
        print(f"  req {rid}: generated {r.tokens[0].tolist()}")

    # reproducibility story: the same commit always serves the same bytes
    engine2 = ServeEngine.from_catalog(lake, commit, cfg, max_len=96,
                                       batch_size=4)
    p = rng.integers(3, cfg.vocab_size, 16).astype(np.int32)
    g1 = engine.generate(np.tile(p, (4, 1)), n_tokens=8).tokens
    g2 = engine2.generate(np.tile(p, (4, 1)), n_tokens=8).tokens
    assert (g1 == g2).all()
    print("same commit ⇒ identical generations ✓")


if __name__ == "__main__":
    main()
