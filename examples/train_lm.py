"""End-to-end driver: train the ~100M paper-demo LM through the replayable
catalog — corpus → packing pipeline → fault-tolerant training (checkpoint
commits + injected failure + bit-exact resume) → WAP publish → replay audit.

This is the paper's technique applied to a training job: every input the
run consumed and every artifact it produced is an immutable catalog object.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
(--full uses the real 12-layer/768-d 100M config; default is the reduced
config so the example finishes in ~a minute on CPU.)
"""

import argparse
import tempfile

import numpy as np

from repro.configs import full_config, smoke_config
from repro.core import Lake
from repro.data import batch_rows, build_data_pipeline, seed_corpus
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true",
                    help="train the real ~100M config (slower)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = (full_config("paper-demo") if args.full
           else smoke_config("paper-demo"))
    tmp = tempfile.mkdtemp(prefix="repro_train_")
    lake = Lake(tmp)
    print(f"lake at {tmp}; model={cfg.name} ({cfg.param_count()/1e6:.1f}M)")

    # 1. data lands as catalog tables via the packing pipeline
    lake.catalog.create_branch("data.main", "main", author="data")
    seed_corpus(lake, "data.main", n_docs=512, seed=7,
                vocab_size=cfg.vocab_size, mean_len=200, author="data")
    res = lake.run(build_data_pipeline(args.seq_len), branch="data.main",
                   author="data")
    print(f"data pipeline run_id={res.run_id}")

    # 2. fault-tolerant training with an injected node failure
    tcfg = TrainerConfig(
        arch=cfg.name, seq_len=args.seq_len, global_batch=args.batch,
        n_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        schedule="wsd",
        schedule_kw={"peak_lr": 3e-4,
                     "warmup_steps": args.steps // 10,
                     "stable_steps": args.steps // 2,
                     "decay_steps": args.steps // 2},
        author="trainer")
    trainer = Trainer(lake, cfg, tcfg, data_branch="data.main",
                      run_name="demo", failure_at=args.steps // 2)
    try:
        trainer.run()
    except RuntimeError as e:
        print(f"!! {e} — restarting from last checkpoint commit")
    out = trainer.run(resume=True)
    print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"({len(out['losses'])} recorded steps, "
          f"{trainer.straggler_events} straggler events)")

    # 3. the data-iterator state is ONE integer — prove resume determinism
    packed = lake.read_table(trainer.run_branch, "packed")
    r1, _ = batch_rows(args.steps // 2,
                       n_rows=packed["tokens"].shape[0],
                       global_batch=args.batch, seed=tcfg.seed)
    r2, _ = batch_rows(args.steps // 2,
                       n_rows=packed["tokens"].shape[0],
                       global_batch=args.batch, seed=tcfg.seed)
    assert (r1 == r2).all()
    print("stateless loader: post-failure batch identical on resume ✓")

    # 4. publish the run through write-audit-publish
    head = trainer.publish("main")
    print(f"WAP-published run branch to main @ {head[:12]}")
    print(f"main tables: {sorted(lake.catalog.tables('main'))}")

    # 5. every checkpoint is time-travelable
    from repro.checkpoint import latest_checkpoint, restore
    c = latest_checkpoint(lake, trainer.run_branch)
    _, _, meta = restore(lake, c)
    print(f"latest checkpoint commit {c[:12]} at step {meta['step']} "
          f"digest={meta.get('params_digest', '')[:16]}…")


if __name__ == "__main__":
    main()
