"""Quickstart — the paper's two use cases, end to end (Fig. 1 + Fig. 4).

Use case #1 (§2): Richard writes pipeline P as a SQL node + a Python node
with implicit parents, runs it on a branch, and gets an immutable run_id.

Use case #2 (§5): last night's production run made an empty training_data;
Richard time-travels to the faulty run, reproduces it bit-exactly on a debug
branch, fixes the code, verifies, and publishes through write-audit-publish.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core import (CodeDrift, Lake, Model, Pipeline, col, lit, model,
                        no_nans, not_empty, publish, sql_model)


def make_pipeline(cutoff_day: int) -> Pipeline:
    # Listing 1: declarative node, parent declared by FROM
    final_table = sql_model(
        "final_table", select=["c1", "c2", "c3"], frm="source_table",
        where=col("transaction_day") >= lit(cutoff_day))

    # Listing 2: Python node, parent declared by Model('final_table')
    @model(python="3.11", pip={"scikit-learn": "1.3.0"})
    def training_data(data=Model("final_table")):
        return {"x": np.stack([data["c1"], data["c2"]], axis=1),
                "label": (data["c3"] > 0.5).astype(np.float32)}

    return Pipeline([final_table, training_data])


def main():
    tmp = tempfile.mkdtemp(prefix="repro_lake_")
    lake = Lake(tmp)
    print(f"lake at {tmp}")

    # --- seed the raw transactions table on main -------------------------
    rng = np.random.default_rng(42)
    n = 5000
    src = {
        "c1": rng.normal(size=n).astype(np.float32),
        "c2": rng.normal(size=n).astype(np.float32),
        "c3": rng.random(n).astype(np.float32),
        "transaction_day": rng.integers(0, 30, n).astype(np.int64),
    }
    snap = lake.io.write_snapshot(src)
    lake.catalog.commit("main", {"source_table": snap}, "raw transactions",
                        _wap_token=True)

    # --- use case #1: develop + run P on a personal branch ---------------
    lake.catalog.create_branch("richard.dev", "main", author="richard")
    pipe = make_pipeline(cutoff_day=7)
    res = lake.run(pipe, branch="richard.dev", author="richard")
    td = lake.read_table("richard.dev", "training_data")
    print(f"[uc1] run_id={res.run_id}  training_data rows={len(td['label'])}")

    manifest = lake.ledger.get(res.run_id)
    print(f"[uc1] manifest pins: data_commit={manifest['data_commit'][:12]} "
          f"code_nodes={list(manifest['code'])} "
          f"runtime={manifest['runtime']['jax']}")

    # --- "production moves on": new data lands upstream -------------------
    src2 = {k: v[: n // 2] for k, v in src.items()}
    lake.write_table("richard.dev", "source_table", src2, author="richard",
                     message="nightly refresh (oops)")

    # --- use case #2: reproduce last night's run (Listing 3) -------------
    #   bauplan checkout richard.debug_branch
    #   bauplan run --id=<run_id>
    #   bauplan query "SELECT COUNT(*) FROM training_data"
    rep = lake.replay(res.run_id, pipe, branch="richard.debug",
                      author="richard")
    count = len(lake.read_table("richard.debug", "training_data")["label"])
    print(f"[uc2] replay bit_exact={rep.bit_exact} COUNT(*)={count}")
    assert rep.bit_exact

    # fix the "bug" (code change) — drift is detected, then allowed
    fixed = make_pipeline(cutoff_day=0)
    try:
        lake.replay(res.run_id, fixed, branch="richard.debug",
                    author="richard")
    except CodeDrift as e:
        print(f"[uc2] code drift detected as expected: {e}")
    rep2 = lake.replay(res.run_id, fixed, branch="richard.debug",
                       author="richard", allow_code_drift=True)
    count2 = len(lake.read_table("richard.debug", "training_data")["label"])
    print(f"[uc2] after fix: rows {count} -> {count2} "
          f"(bit_exact={rep2.bit_exact} — expected False, code changed)")

    # --- publish through Write-Audit-Publish (§5.5) ----------------------
    head = publish(lake.catalog, lake.io, "richard.debug",
                   [not_empty("training_data"), no_nans("training_data")],
                   author="richard")
    print(f"[wap] published to main @ {head[:12]}; "
          f"tables={sorted(lake.catalog.tables('main'))}")


if __name__ == "__main__":
    main()
